//! `.fatm` on-disk layout: constants, the checked little-endian reader,
//! and the section writer (DESIGN.md §11.1).
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"FATM0001"
//! 8       8     file_size (u64 LE) — must equal the real byte length
//! 16      8     digest (u64 LE) — FNV-1a 64 over bytes[24..file_size]
//! 24      4     isa_tag (u32 LE) — packing ISA of the panel section
//! 28      4     section_count (u32 LE)
//! 32      32    reserved (zero; covered by the digest region)
//! 64      24×n  section TOC: (kind u32, reserved u32, off u64, len u64)
//! ...           sections, each starting at a 64-byte-aligned offset
//! ```
//!
//! Sections: `GRAPH` (the graph IR as `graph.json` text), `PLAN` (the
//! compiled schedule + parameter tables, hand-serialized little-endian,
//! referencing panel blobs by offset), `PANEL` (concatenated raw i8
//! blobs — unpacked weights and prepacked SIMD panels — each blob
//! 64-byte aligned within the section). The 64-byte discipline keeps
//! every panel cache-line aligned under `mmap` (the mapping base is
//! page-aligned, and 4096 ≡ 0 mod 64); the heap fallback only
//! guarantees byte alignment, which is all `i8` data needs.
//!
//! Every multi-byte integer in the file is little-endian. The
//! [`Reader`] here is the one parsing primitive for both the `.fatm`
//! loader and the hardened `.fatw` reader: every read is
//! length-checked, and every length-prefixed allocation is validated
//! against the remaining bytes *before* allocating, so truncated or
//! hostile inputs fail with an error instead of a panic or an OOM.

use anyhow::{bail, ensure, Result};

use crate::int8::kernels::Isa;

/// File magic, bumped with the format version.
pub const MAGIC: &[u8; 8] = b"FATM0001";
/// Fixed header length (bytes); the TOC follows immediately.
pub const HEADER_LEN: usize = 64;
/// Bytes per section TOC entry.
pub const TOC_ENTRY_LEN: usize = 24;
/// Section alignment (and intra-PANEL blob alignment).
pub const ALIGN: usize = 64;
/// First digested byte: everything after the digest field itself.
pub const DIGEST_START: usize = 24;

/// Section kinds.
pub const SEC_GRAPH: u32 = 1;
pub const SEC_PLAN: u32 = 2;
pub const SEC_PANEL: u32 = 3;
/// Sections every v1 file carries, in file order.
pub const SECTIONS: [u32; 3] = [SEC_GRAPH, SEC_PLAN, SEC_PANEL];

/// PLAN-section format version (bumped independently of the magic for
/// additive changes). v2 appends a per-layer GEMM [`Blocking`] table
/// (autotuner output, DESIGN.md §12); v3 appends the shift-only requant
/// table (`QLayer::requant_shift`, pow2 exports) and a bits tag on each
/// packed panel record (int4 nibble panels, DESIGN.md §13); v4 appends
/// the per-layer fused implicit-GEMM bit (`QLayer::fused`, DESIGN.md
/// §14) between the shift table and the packed-panel record. Older
/// files are still readable: v1/v2 layers get [`Blocking::default`]
/// (v1), no shift table, and 8-bit panels; v1–v3 layers default the
/// fused bit to "on for every packed layer" so existing tuned
/// artifacts inherit the fused win without a re-export.
///
/// [`Blocking`]: crate::int8::kernels::Blocking
/// [`Blocking::default`]: crate::int8::kernels::Blocking::default
pub const PLAN_VERSION: u32 = 4;
/// Oldest PLAN version this build still reads.
pub const PLAN_VERSION_MIN: u32 = 1;

/// Wire tag for a packing ISA.
pub fn isa_tag(isa: Isa) -> u32 {
    match isa {
        Isa::Scalar => 0,
        Isa::Sse2 => 1,
        Isa::Avx2 => 2,
        Isa::Avx512Vnni => 3,
    }
}

/// Inverse of [`isa_tag`]; unknown tags are a format error.
pub fn isa_from_tag(tag: u32) -> Result<Isa> {
    Ok(match tag {
        0 => Isa::Scalar,
        1 => Isa::Sse2,
        2 => Isa::Avx2,
        3 => Isa::Avx512Vnni,
        other => bail!("unknown ISA tag {other} (want 0..=3)"),
    })
}

/// Round `n` up to the next [`ALIGN`] boundary.
pub fn align_up(n: usize) -> usize {
    n.div_ceil(ALIGN) * ALIGN
}

/// Checked little-endian cursor over a byte slice. Every accessor
/// errors (never panics) on truncation, and the `vec_*` readers bound
/// their allocation by the remaining input length first.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Context string prefixed to every error (e.g. the section name).
    what: &'static str,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8], what: &'static str) -> Reader<'a> {
        Reader { buf, pos: 0, what }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Current offset from the start of the buffer.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// True when every byte was consumed (trailing garbage detector).
    pub fn exhausted(&self) -> bool {
        self.remaining() == 0
    }

    /// Take the next `n` raw bytes.
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= self.remaining(),
            "{}: truncated at byte {} (need {n} more, have {})",
            self.what,
            self.pos,
            self.remaining()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    pub fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    /// `f32` transported as raw bits — exact for every value including
    /// NaN payloads (no decimal round-trip).
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// A `u32` that must fit in `usize` and stay within `cap` (index
    /// and count fields).
    pub fn usize_capped(&mut self, cap: usize, field: &str) -> Result<usize> {
        let v = self.u32()? as usize;
        ensure!(
            v <= cap,
            "{}: {field} = {v} exceeds cap {cap}",
            self.what
        );
        Ok(v)
    }

    /// Length-prefixed UTF-8 string (u32 length).
    pub fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let raw = self.bytes(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|e| anyhow::anyhow!("{}: bad utf-8 string: {e}", self.what))
    }

    /// Length-prefixed `Vec<i32>`; the element count is validated
    /// against the remaining bytes before any allocation happens.
    pub fn vec_i32(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        ensure!(
            n <= self.remaining() / 4,
            "{}: i32 array of {n} elements exceeds remaining {} bytes",
            self.what,
            self.remaining()
        );
        (0..n).map(|_| self.i32()).collect()
    }

    /// Length-prefixed `Vec<f32>` (bit-exact transport).
    pub fn vec_f32(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        ensure!(
            n <= self.remaining() / 4,
            "{}: f32 array of {n} elements exceeds remaining {} bytes",
            self.what,
            self.remaining()
        );
        (0..n).map(|_| self.f32()).collect()
    }

    /// Length-prefixed `Vec<(i32, i32)>` (requant multiplier pairs).
    pub fn vec_i32_pair(&mut self) -> Result<Vec<(i32, i32)>> {
        let n = self.u32()? as usize;
        ensure!(
            n <= self.remaining() / 8,
            "{}: pair array of {n} elements exceeds remaining {} bytes",
            self.what,
            self.remaining()
        );
        (0..n).map(|_| Ok((self.i32()?, self.i32()?))).collect()
    }
}

/// Little-endian serializer mirroring [`Reader`].
#[derive(Default)]
pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    pub fn string(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn vec_i32(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.i32(x);
        }
    }

    pub fn vec_f32(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f32(x);
        }
    }

    pub fn vec_i32_pair(&mut self, v: &[(i32, i32)]) {
        self.u32(v.len() as u32);
        for &(a, b) in v {
            self.i32(a);
            self.i32(b);
        }
    }
}

/// View `&[i8]` as raw bytes (same size, alignment 1, all bit patterns
/// valid both ways).
pub fn i8_as_bytes(s: &[i8]) -> &[u8] {
    // SAFETY: i8 and u8 are layout-identical; lifetime and length carry
    // over unchanged.
    unsafe { std::slice::from_raw_parts(s.as_ptr() as *const u8, s.len()) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reader_round_trips_writer() {
        let mut w = Writer::default();
        w.u32(7);
        w.u64(1 << 40);
        w.i32(-9);
        w.f32(f32::MIN_POSITIVE);
        w.string("node.id");
        w.vec_i32(&[1, -2, 3]);
        w.vec_f32(&[0.5, -0.0]);
        w.vec_i32_pair(&[(1, 2), (-3, 4)]);
        let mut r = Reader::new(&w.buf, "test");
        assert_eq!(r.u32().unwrap(), 7);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.i32().unwrap(), -9);
        assert_eq!(r.f32().unwrap().to_bits(), f32::MIN_POSITIVE.to_bits());
        assert_eq!(r.string().unwrap(), "node.id");
        assert_eq!(r.vec_i32().unwrap(), vec![1, -2, 3]);
        let f = r.vec_f32().unwrap();
        assert_eq!(f[0], 0.5);
        assert_eq!(f[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.vec_i32_pair().unwrap(), vec![(1, 2), (-3, 4)]);
        assert!(r.exhausted());
    }

    #[test]
    fn truncation_errors_not_panics() {
        let mut w = Writer::default();
        w.vec_i32(&[1, 2, 3, 4]);
        for cut in 0..w.buf.len() {
            let mut r = Reader::new(&w.buf[..cut], "trunc");
            assert!(r.vec_i32().is_err(), "prefix {cut} parsed");
        }
    }

    #[test]
    fn hostile_length_prefix_rejected_before_allocating() {
        // claims 2^32-1 elements with 4 bytes of payload
        let mut bytes = (u32::MAX).to_le_bytes().to_vec();
        bytes.extend_from_slice(&[0u8; 4]);
        let mut r = Reader::new(&bytes, "hostile");
        assert!(r.vec_i32().is_err());
        let mut r2 = Reader::new(&bytes, "hostile");
        assert!(r2.string().is_err());
    }

    #[test]
    fn isa_tags_round_trip() {
        for isa in [Isa::Scalar, Isa::Sse2, Isa::Avx2, Isa::Avx512Vnni] {
            assert_eq!(isa_from_tag(isa_tag(isa)).unwrap(), isa);
        }
        assert!(isa_from_tag(4).is_err());
        assert!(isa_from_tag(u32::MAX).is_err());
    }

    #[test]
    fn alignment() {
        assert_eq!(align_up(0), 0);
        assert_eq!(align_up(1), 64);
        assert_eq!(align_up(64), 64);
        assert_eq!(align_up(65), 128);
    }
}
