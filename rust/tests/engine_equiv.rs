//! Equivalence tests for the planned/parallel int8 engine: the compiled
//! plan + buffer arena + threaded kernels must be bit-exact with the
//! sequential reference interpreter (`QModel::run_quant_ref`) and with
//! themselves across thread counts {1, 2, 8}, for all four quantization
//! modes and odd shapes. No artifacts are needed: the model is built
//! synthetically through the real `quant::export::build_qmodel` path.

use std::collections::BTreeMap;

use fat::int8::engine::QNode;
use fat::int8::{QModel, QTensor};
use fat::model::store::{Site, SitesJson};
use fat::model::{GraphDef, Op};
use fat::quant::calibrate::CalibStats;
use fat::quant::export::{build_qmodel, build_qmodel_with, QuantKnobs, QuantMode, Trained};
use fat::tensor::Tensor;
use fat::util::prop;

/// Residual branch + DWS chain + dense head; odd channel counts, odd
/// input size, a stride-2 dwconv, and both relu flavours.
const GRAPH: &str = r#"{
  "name": "equiv", "num_classes": 4,
  "nodes": [
    {"id": "input", "op": "input", "inputs": [], "shape": [9, 9, 3]},
    {"id": "c0", "op": "conv", "inputs": ["input"], "k": 3, "stride": 1, "cin": 3, "cout": 5, "bias": true},
    {"id": "r0", "op": "relu6", "inputs": ["c0"]},
    {"id": "dw", "op": "dwconv", "inputs": ["r0"], "k": 3, "stride": 2, "ch": 5, "bias": true},
    {"id": "r1", "op": "relu", "inputs": ["dw"]},
    {"id": "c1", "op": "conv", "inputs": ["r1"], "k": 1, "stride": 1, "cin": 5, "cout": 7, "bias": true},
    {"id": "c2", "op": "conv", "inputs": ["r1"], "k": 1, "stride": 1, "cin": 5, "cout": 7, "bias": true},
    {"id": "ad", "op": "add", "inputs": ["c1", "c2"]},
    {"id": "g", "op": "gap", "inputs": ["ad"]},
    {"id": "d", "op": "dense", "inputs": ["g"], "cin": 7, "cout": 4, "bias": true}
  ]}"#;

/// Small stride-2 conv net over a 7x7x2 input (odd spatial remainders).
const GRAPH_ODD: &str = r#"{
  "name": "odd", "num_classes": 5,
  "nodes": [
    {"id": "input", "op": "input", "inputs": [], "shape": [7, 7, 2]},
    {"id": "c0", "op": "conv", "inputs": ["input"], "k": 3, "stride": 2, "cin": 2, "cout": 3, "bias": true},
    {"id": "r0", "op": "relu6", "inputs": ["c0"]},
    {"id": "dw", "op": "dwconv", "inputs": ["r0"], "k": 3, "stride": 1, "ch": 3, "bias": true},
    {"id": "r1", "op": "relu", "inputs": ["dw"]},
    {"id": "g", "op": "gap", "inputs": ["r1"]},
    {"id": "d", "op": "dense", "inputs": ["g"], "cin": 3, "cout": 5, "bias": true}
  ]}"#;

fn weights_for(g: &GraphDef) -> BTreeMap<String, Tensor> {
    let mut w = BTreeMap::new();
    let mut seed = 100u64;
    for n in g.conv_like() {
        let (wlen, cout) = match n.op {
            Op::Conv => (n.k * n.k * n.cin * n.cout, n.cout),
            Op::DwConv => (n.k * n.k * n.ch, n.ch),
            Op::Dense => (n.cin * n.cout, n.cout),
            _ => unreachable!(),
        };
        w.insert(
            format!("{}.w", n.id),
            Tensor::f32(vec![wlen], prop::f32s(seed, wlen, -0.6, 0.6)),
        );
        w.insert(
            format!("{}.b", n.id),
            Tensor::f32(vec![cout], prop::f32s(seed + 1, cout, -0.2, 0.2)),
        );
        seed += 2;
    }
    w
}

fn sites_for(g: &GraphDef) -> SitesJson {
    SitesJson {
        sites: g
            .sites()
            .into_iter()
            .map(|(id, unsigned)| Site { id, unsigned })
            .collect(),
        channel_stats: vec![],
        weight_order: g.folded_weight_order(),
        val_acc_fp_pretrain: -1.0,
    }
}

fn stats_for(s: &SitesJson) -> CalibStats {
    let mut st = CalibStats::new(s.sites.len());
    for (i, site) in s.sites.iter().enumerate() {
        let lo = if site.unsigned { 0.0 } else { -2.5 - 0.1 * i as f32 };
        st.site_minmax[i].update(lo, 3.0 + 0.2 * i as f32);
    }
    st.batches = 1;
    st
}

fn build(graph: &str, mode: QuantMode) -> QModel {
    let g = GraphDef::from_json(graph).unwrap();
    let w = weights_for(&g);
    let s = sites_for(&g);
    let st = stats_for(&s);
    let tr = Trained::identity(&g, mode, s.sites.len());
    build_qmodel(&g, &w, &s, &st, mode, &tr).unwrap()
}

fn build_knobs(graph: &str, mode: QuantMode, knobs: QuantKnobs) -> QModel {
    let g = GraphDef::from_json(graph).unwrap();
    let w = weights_for(&g);
    let s = sites_for(&g);
    let st = stats_for(&s);
    let tr = Trained::identity(&g, mode, s.sites.len());
    build_qmodel_with(&g, &w, &s, &st, mode, &tr, knobs).unwrap()
}

fn input_for(g: &GraphDef, batch: usize, seed: u64) -> Tensor {
    let sh = g.node("input").unwrap().input_shape.clone().unwrap();
    let len = batch * sh[0] * sh[1] * sh[2];
    Tensor::f32(
        vec![batch, sh[0], sh[1], sh[2]],
        prop::f32s(seed, len, -0.5, 3.0),
    )
}

fn quantized_input(qm: &QModel, x: &Tensor) -> QTensor {
    QTensor::quantize(x.shape.clone(), x.as_f32().unwrap(), qm.input_qp)
}

#[test]
fn planned_engine_matches_reference_all_modes() {
    for mode in QuantMode::all() {
        let qm = build(GRAPH, mode);
        let x = input_for(&qm.graph, 5, 7);
        let q = quantized_input(&qm, &x);
        let want = qm.run_quant_ref(q.clone()).unwrap();
        assert_eq!(want.shape, vec![5, 4]);
        for t in [1usize, 2, 8] {
            let got = qm.run_quant_with(q.clone(), t).unwrap();
            assert_eq!(got.shape, want.shape, "{mode:?} t={t}");
            assert_eq!(got.data, want.data, "{mode:?} t={t}");
            assert_eq!(got.qp, want.qp, "{mode:?} t={t}");
        }
    }
}

#[test]
fn planned_engine_matches_reference_odd_shapes() {
    for mode in [QuantMode::SymScalar, QuantMode::AsymVector] {
        let qm = build(GRAPH_ODD, mode);
        for batch in [1usize, 3] {
            let x = input_for(&qm.graph, batch, 11 + batch as u64);
            let q = quantized_input(&qm, &x);
            let want = qm.run_quant_ref(q.clone()).unwrap();
            for t in [1usize, 2, 8] {
                let got = qm.run_quant_with(q.clone(), t).unwrap();
                assert_eq!(got.data, want.data, "{mode:?} b={batch} t={t}");
            }
        }
    }
}

#[test]
fn batch_sharding_bit_exact_across_thread_counts() {
    let qm = build(GRAPH, QuantMode::SymVector);
    let x = input_for(&qm.graph, 7, 21); // odd batch vs every shard count
    let base = qm.run_batch_with(&x, 1).unwrap();
    assert_eq!(base.shape, vec![7, 4]);
    for t in [2usize, 3, 8, 16] {
        let got = qm.run_batch_with(&x, t).unwrap();
        assert_eq!(got.shape, base.shape, "t={t}");
        let a = base.as_f32().unwrap();
        let b = got.as_f32().unwrap();
        for i in 0..a.len() {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "t={t} i={i}");
        }
    }
}

#[test]
fn run_batch_agrees_with_reference_interpreter() {
    let qm = build(GRAPH, QuantMode::AsymScalar);
    let x = input_for(&qm.graph, 4, 33);
    let want = qm
        .run_quant_ref(quantized_input(&qm, &x))
        .unwrap()
        .dequantize();
    let got = qm.run_batch(&x).unwrap(); // env-default worker count
    let g = got.as_f32().unwrap();
    assert_eq!(g.len(), want.len());
    for i in 0..want.len() {
        assert_eq!(g[i].to_bits(), want[i].to_bits(), "logit {i}");
    }
}

/// Run the reference interpreter vs the planned engine across threads
/// {1, 2, 8} and assert bit-exact logits.
fn assert_engine_matches_ref(qm: &QModel, seed: u64, tag: &str) {
    let x = input_for(&qm.graph, 5, seed);
    let q = quantized_input(qm, &x);
    let want = qm.run_quant_ref(q.clone()).unwrap();
    for t in [1usize, 2, 8] {
        let got = qm.run_quant_with(q.clone(), t).unwrap();
        assert_eq!(got.data, want.data, "{tag} t={t}");
        assert_eq!(got.qp, want.qp, "{tag} t={t}");
    }
}

#[test]
fn pow2_export_takes_shift_epilogue_everywhere() {
    for mode in QuantMode::all() {
        let knobs = QuantKnobs { pow2: true, ..QuantKnobs::default() };
        let qm = build_knobs(GRAPH, mode, knobs);
        // all 5 conv-like layers (c0, dw, c1, c2, d) collapse to shifts
        let (sh, mu, b4, b8) = qm.epilogue_summary();
        assert_eq!((sh, mu, b4, b8), (5, 0, 0, 5), "{mode:?}");
        // every serialized (m0, shift) pair must agree with its shift:
        // quantize_multiplier(2^-s) == (1 << 30, s - 1) exactly.
        for p in &qm.plan.params {
            if let QNode::Layer(l) = p {
                let sh = l.requant_shift.as_ref().expect("pow2 layer shift");
                assert_eq!(sh.len(), l.requant.len(), "{mode:?}");
                for (c, &s) in sh.iter().enumerate() {
                    assert_eq!(l.requant[c], (1 << 30, s - 1), "{mode:?} c={c}");
                }
            }
        }
        assert_engine_matches_ref(&qm, 7, &format!("pow2 {mode:?}"));
    }
}

#[test]
fn int4_export_packs_nibbles_and_matches_reference() {
    for mode in QuantMode::all() {
        let knobs = QuantKnobs { w_bits: 4, ..QuantKnobs::default() };
        let qm = build_knobs(GRAPH, mode, knobs);
        // c0, c1, c2, d pack int4; depthwise dw stays unpacked (int8)
        let (sh, mu, b4, b8) = qm.epilogue_summary();
        assert_eq!((sh, mu, b4, b8), (0, 5, 4, 1), "{mode:?}");
        for p in &qm.plan.params {
            if let QNode::Layer(l) = p {
                if let Some(pw) = &l.packed {
                    assert_eq!(pw.bits(), 4, "{mode:?}");
                }
                // int4 quantized weights never leave [-7, 7]
                assert!(
                    l.w_q.iter().all(|&w| (-7..=7).contains(&w)),
                    "{mode:?}"
                );
            }
        }
        assert_engine_matches_ref(&qm, 13, &format!("int4 {mode:?}"));
    }
}

#[test]
fn pow2_int4_combined_matches_reference() {
    for (graph, layers, packed4) in [(GRAPH, 5usize, 4usize), (GRAPH_ODD, 3, 2)] {
        let knobs = QuantKnobs { pow2: true, w_bits: 4 };
        let qm = build_knobs(graph, QuantMode::SymVector, knobs);
        let (sh, mu, b4, b8) = qm.epilogue_summary();
        assert_eq!((sh, mu, b4, b8), (layers, 0, packed4, layers - packed4));
        assert_engine_matches_ref(&qm, 29, "pow2+int4");
    }
}

#[test]
fn plan_reuses_buffers_and_skips_fused_relus() {
    let qm = build(GRAPH, QuantMode::SymScalar);
    // 7 compute nodes (c0, dw, c1, c2, ad, g, d); relus compile away
    assert_eq!(qm.plan.steps.len(), 7);
    assert!(qm.plan.steps.iter().all(|s| s.id != "r0" && s.id != "r1"));
    // liveness reuse keeps the working set far below one-slot-per-node
    assert!(
        qm.plan.num_slots <= 4,
        "expected <= 4 slots, got {}",
        qm.plan.num_slots
    );
    assert!(qm.plan.steps.iter().any(|s| !s.frees.is_empty()));
    // repeated runs over recycled buffers stay deterministic
    let x = input_for(&qm.graph, 2, 5);
    let q = quantized_input(&qm, &x);
    let first = qm.run_quant_with(q.clone(), 2).unwrap();
    let second = qm.run_quant_with(q, 2).unwrap();
    assert_eq!(first.data, second.data);
}
