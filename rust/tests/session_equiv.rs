//! Equivalence tests for the redesigned API (no artifacts needed):
//!
//! * the session-layer export path (`QuantSpec` + `ThresholdSet` +
//!   `export_with`) must be bit-exact with the pre-redesign path
//!   (`Trained` + `build_qmodel`) for every [`QuantMode`];
//! * the [`Int8Engine`] serving handle with its pooled per-worker
//!   execution states must be bit-exact with the bare
//!   `QModel::run_batch_with` across repeated calls and thread counts
//!   {1, 2, 8};
//! * [`ThresholdSet::from_trainables`] must accept exactly the trainable
//!   key grammar and reject everything else (the old
//!   `Pipeline::trained_of_map` silently dropped unknown keys).

use std::collections::BTreeMap;

use fat::int8::serve::{EngineOptions, Int8Engine};
use fat::int8::QModel;
use fat::model::store::{Site, SitesJson};
use fat::model::{GraphDef, Op};
use fat::quant::calibrate::CalibStats;
use fat::quant::export::{build_qmodel, QuantMode, Trained};
use fat::quant::session::{export_with, QuantSpec, ThresholdSet};
use fat::tensor::Tensor;
use fat::util::prop;

/// Residual branch + DWS chain + dense head; odd channel counts, odd
/// input size, a stride-2 dwconv, and both relu flavours (the same
/// geometry as `engine_equiv.rs`).
const GRAPH: &str = r#"{
  "name": "equiv", "num_classes": 4,
  "nodes": [
    {"id": "input", "op": "input", "inputs": [], "shape": [9, 9, 3]},
    {"id": "c0", "op": "conv", "inputs": ["input"], "k": 3, "stride": 1, "cin": 3, "cout": 5, "bias": true},
    {"id": "r0", "op": "relu6", "inputs": ["c0"]},
    {"id": "dw", "op": "dwconv", "inputs": ["r0"], "k": 3, "stride": 2, "ch": 5, "bias": true},
    {"id": "r1", "op": "relu", "inputs": ["dw"]},
    {"id": "c1", "op": "conv", "inputs": ["r1"], "k": 1, "stride": 1, "cin": 5, "cout": 7, "bias": true},
    {"id": "c2", "op": "conv", "inputs": ["r1"], "k": 1, "stride": 1, "cin": 5, "cout": 7, "bias": true},
    {"id": "ad", "op": "add", "inputs": ["c1", "c2"]},
    {"id": "g", "op": "gap", "inputs": ["ad"]},
    {"id": "d", "op": "dense", "inputs": ["g"], "cin": 7, "cout": 4, "bias": true}
  ]}"#;

fn weights_for(g: &GraphDef) -> BTreeMap<String, Tensor> {
    let mut w = BTreeMap::new();
    let mut seed = 100u64;
    for n in g.conv_like() {
        let (wlen, cout) = match n.op {
            Op::Conv => (n.k * n.k * n.cin * n.cout, n.cout),
            Op::DwConv => (n.k * n.k * n.ch, n.ch),
            Op::Dense => (n.cin * n.cout, n.cout),
            _ => unreachable!(),
        };
        w.insert(
            format!("{}.w", n.id),
            Tensor::f32(vec![wlen], prop::f32s(seed, wlen, -0.6, 0.6)),
        );
        w.insert(
            format!("{}.b", n.id),
            Tensor::f32(vec![cout], prop::f32s(seed + 1, cout, -0.2, 0.2)),
        );
        seed += 2;
    }
    w
}

fn sites_for(g: &GraphDef) -> SitesJson {
    SitesJson {
        sites: g
            .sites()
            .into_iter()
            .map(|(id, unsigned)| Site { id, unsigned })
            .collect(),
        channel_stats: vec![],
        weight_order: g.folded_weight_order(),
        val_acc_fp_pretrain: -1.0,
    }
}

fn stats_for(s: &SitesJson) -> CalibStats {
    let mut st = CalibStats::new(s.sites.len());
    for (i, site) in s.sites.iter().enumerate() {
        let lo = if site.unsigned { 0.0 } else { -2.5 - 0.1 * i as f32 };
        st.site_minmax[i].update(lo, 3.0 + 0.2 * i as f32);
    }
    st.batches = 1;
    st
}

struct Parts {
    g: GraphDef,
    w: BTreeMap<String, Tensor>,
    s: SitesJson,
    st: CalibStats,
}

fn parts() -> Parts {
    let g = GraphDef::from_json(GRAPH).unwrap();
    let w = weights_for(&g);
    let s = sites_for(&g);
    let st = stats_for(&s);
    Parts { g, w, s, st }
}

/// Pre-redesign export path: `Trained` straight into `build_qmodel`.
fn legacy_model(p: &Parts, mode: QuantMode) -> QModel {
    let tr = Trained::identity(&p.g, mode, p.s.sites.len());
    build_qmodel(&p.g, &p.w, &p.s, &p.st, mode, &tr).unwrap()
}

/// Redesigned export path: `QuantSpec` + `ThresholdSet` + `export_with`.
fn session_model(p: &Parts, mode: QuantMode) -> QModel {
    let spec = QuantSpec::from_mode(mode);
    let ts = ThresholdSet::identity(&p.g, mode, p.s.sites.len());
    export_with(&p.g, &p.w, &p.s, &p.st, &spec, &ts).unwrap()
}

fn input_for(g: &GraphDef, batch: usize, seed: u64) -> Tensor {
    let sh = g.node("input").unwrap().input_shape.clone().unwrap();
    let len = batch * sh[0] * sh[1] * sh[2];
    Tensor::f32(
        vec![batch, sh[0], sh[1], sh[2]],
        prop::f32s(seed, len, -0.5, 3.0),
    )
}

fn assert_logits_eq(a: &Tensor, b: &Tensor, tag: &str) {
    assert_eq!(a.shape, b.shape, "{tag}");
    let (af, bf) = (a.as_f32().unwrap(), b.as_f32().unwrap());
    for i in 0..af.len() {
        assert_eq!(af[i].to_bits(), bf[i].to_bits(), "{tag} logit {i}");
    }
}

#[test]
fn session_export_matches_legacy_all_modes() {
    let p = parts();
    for mode in QuantMode::all() {
        let legacy = legacy_model(&p, mode);
        let session = session_model(&p, mode);
        let x = input_for(&p.g, 5, 7);
        let want = legacy.run_batch_with(&x, 1).unwrap();
        let got = session.run_batch_with(&x, 1).unwrap();
        assert_logits_eq(&want, &got, &format!("{mode:?}"));
    }
}

#[test]
fn threshold_set_from_trainables_matches_manual_trained() {
    let p = parts();
    let mode = QuantMode::AsymVector;
    let nsites = p.s.sites.len();
    // a trainable map with every key class exercised
    let mut map = BTreeMap::new();
    map.insert(
        "act_at".to_string(),
        Tensor::f32(vec![nsites], vec![0.05; nsites]),
    );
    map.insert(
        "act_ar".to_string(),
        Tensor::f32(vec![nsites], vec![0.93; nsites]),
    );
    map.insert("w_a:c1".to_string(), Tensor::f32(vec![7], vec![0.9; 7]));
    let ts =
        ThresholdSet::from_trainables(&p.g, mode, nsites, &map).unwrap();
    // the manual pre-redesign equivalent
    let mut tr = Trained::identity(&p.g, mode, nsites);
    tr.act_at = vec![0.05; nsites];
    tr.act_ar = vec![0.93; nsites];
    tr.w_a.insert("c1".to_string(), vec![0.9; 7]);
    let legacy = build_qmodel(&p.g, &p.w, &p.s, &p.st, mode, &tr).unwrap();
    let session = export_with(
        &p.g,
        &p.w,
        &p.s,
        &p.st,
        &QuantSpec::from_mode(mode),
        &ts,
    )
    .unwrap();
    let x = input_for(&p.g, 3, 21);
    assert_logits_eq(
        &legacy.run_batch_with(&x, 1).unwrap(),
        &session.run_batch_with(&x, 1).unwrap(),
        "finetuned-map equivalence",
    );
}

#[test]
fn from_trainables_rejects_unknown_and_misshaped_keys() {
    let p = parts();
    let nsites = p.s.sites.len();
    // unknown key: the old trained_of_map silently dropped this
    let mut map = BTreeMap::new();
    map.insert(
        "act_a_typo".to_string(),
        Tensor::f32(vec![nsites], vec![1.0; nsites]),
    );
    let err =
        ThresholdSet::from_trainables(&p.g, QuantMode::SymScalar, nsites, &map)
            .unwrap_err();
    assert!(
        err.to_string().contains("unknown trainable key"),
        "{err}"
    );
    // unknown node behind the w_a: prefix
    let mut map = BTreeMap::new();
    map.insert("w_a:ghost".to_string(), Tensor::f32(vec![1], vec![1.0]));
    assert!(ThresholdSet::from_trainables(
        &p.g,
        QuantMode::SymScalar,
        nsites,
        &map
    )
    .is_err());
    // wrong per-site length
    let mut map = BTreeMap::new();
    map.insert("act_a".to_string(), Tensor::f32(vec![1], vec![1.0]));
    assert!(ThresholdSet::from_trainables(
        &p.g,
        QuantMode::SymScalar,
        nsites,
        &map
    )
    .is_err());
}

#[test]
fn engine_pool_reuse_bit_exact_across_threads_and_calls() {
    let p = parts();
    let qm = legacy_model(&p, QuantMode::SymVector);
    let x = input_for(&p.g, 7, 33); // odd batch vs every shard count
    let want = qm.run_batch_with(&x, 1).unwrap();
    for t in [1usize, 2, 8] {
        let engine =
            Int8Engine::new(qm.clone(), EngineOptions::threads(t));
        assert_eq!(engine.threads(), t);
        for call in 0..3 {
            // repeated calls run on recycled pooled states
            let got = engine.infer_batch(&x).unwrap();
            assert_logits_eq(&want, &got, &format!("t={t} call={call}"));
        }
        let pooled = engine.pooled_states();
        assert!(
            (1..=t.min(7)).contains(&pooled),
            "t={t}: expected 1..={} resting states, got {pooled}",
            t.min(7)
        );
        // the pool is recycled, not regrown, on further calls
        let _ = engine.infer_batch(&x).unwrap();
        assert_eq!(engine.pooled_states(), pooled, "t={t}");
    }
}

#[test]
fn engine_handle_clones_share_model_and_pool() {
    let p = parts();
    let engine = Int8Engine::new(
        legacy_model(&p, QuantMode::SymScalar),
        EngineOptions::threads(2),
    );
    let clone = engine.clone();
    let x = input_for(&p.g, 4, 5);
    let a = engine.infer_batch(&x).unwrap();
    let b = clone.infer_batch(&x).unwrap();
    assert_logits_eq(&a, &b, "clone");
    // both handles drain/refill the same pool
    assert_eq!(engine.pooled_states(), clone.pooled_states());
    assert_eq!(engine.param_bytes(), clone.param_bytes());
}

#[test]
fn infer_u8_matches_infer_batch() {
    let p = parts();
    let engine = Int8Engine::new(
        legacy_model(&p, QuantMode::AsymScalar),
        EngineOptions::threads(1),
    );
    let sh = p.g.node("input").unwrap().input_shape.clone().unwrap();
    let n: usize = sh.iter().product();
    let bytes: Vec<u8> =
        (0..n).map(|i| ((i * 37 + 11) % 256) as u8).collect();
    let x: Vec<f32> = bytes.iter().map(|&b| b as f32 / 255.0).collect();
    let t = Tensor::f32(vec![1, sh[0], sh[1], sh[2]], x);
    let want = engine.infer_batch(&t).unwrap();
    let got = engine.infer(&bytes).unwrap();
    let wf = want.as_f32().unwrap();
    assert_eq!(wf.len(), got.len());
    for i in 0..got.len() {
        assert_eq!(wf[i].to_bits(), got[i].to_bits(), "logit {i}");
    }
    // wrong byte count is a typed error, not a panic
    assert!(engine.infer(&bytes[..n - 1]).is_err());
}

#[test]
fn engine_options_default_follows_env_knob() {
    let p = parts();
    let engine = Int8Engine::new(
        legacy_model(&p, QuantMode::SymScalar),
        EngineOptions::default(),
    );
    assert_eq!(engine.threads(), fat::util::threads::fat_threads());
    let pinned = Int8Engine::new(
        legacy_model(&p, QuantMode::SymScalar),
        EngineOptions::threads(3),
    );
    assert_eq!(pinned.threads(), 3);
}
