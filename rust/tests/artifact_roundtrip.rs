//! `.fatm` artifact round-trip battery (DESIGN.md §11): a compiled
//! model saved to disk and loaded back — zero-copy mmap or heap — must
//! serve logits **bit-identical** to the in-memory export, across every
//! runnable kernel ISA × thread count, including when the artifact's
//! packing-ISA tag forces a repack on load. Also pins down the
//! determinism contract (same model → same bytes → same etag) and the
//! registry/server integration (`load_artifact`, `/models`, per-model
//! etag in `/stats`). (CI re-runs this file under `FAT_THREADS=1`
//! and `8`.)

use std::io::{Read, Write};
use std::path::PathBuf;

use fat::artifact::{self, LoadOptions};
use fat::int8::serve::{EngineOptions, InferClient};
use fat::int8::{ExecState, Isa, QModel, QTensor};
use fat::model::builtin;
use fat::net::{ModelRegistry, Server, ServerOptions};
use fat::quant::calibrate::CalibStats;
use fat::quant::export::{
    build_qmodel, build_qmodel_with, QuantKnobs, QuantMode, Trained,
};
use fat::util::json::Json;

/// Compile a builtin model with synthetic calibration ranges —
/// deterministic, artifact-free, and exercising conv / dwconv / dense /
/// add / gap params depending on the model.
fn build(name: &str) -> QModel {
    let (g, s, w) = builtin::load(name).unwrap();
    let mut st = CalibStats::new(s.sites.len());
    for (i, site) in s.sites.iter().enumerate() {
        let lo = if site.unsigned { 0.0 } else { -2.0 - 0.1 * i as f32 };
        st.site_minmax[i].update(lo, 2.5 + 0.2 * i as f32);
    }
    st.batches = 1;
    let tr = Trained::identity(&g, QuantMode::SymVector, s.sites.len());
    build_qmodel(&g, &w, &s, &st, QuantMode::SymVector, &tr).unwrap()
}

fn input_shape(qm: &QModel) -> Vec<usize> {
    qm.graph
        .nodes
        .iter()
        .find(|n| n.op == fat::model::Op::Input)
        .and_then(|n| n.input_shape.clone())
        .expect("builtin model has a shaped input")
}

fn quant_input(qm: &QModel, img: usize) -> QTensor {
    let sh = input_shape(qm);
    let per_img: usize = sh.iter().product();
    let x: Vec<f32> = (0..per_img)
        .map(|i| ((i * 37 + img * 101 + 5) % 256) as f32 / 255.0)
        .collect();
    QTensor::quantize(vec![1, sh[0], sh[1], sh[2]], &x, qm.input_qp)
}

/// Quantized logits under an explicit (threads, isa) execution state.
fn logits(qm: &QModel, img: usize, threads: usize, isa: Isa) -> QTensor {
    let mut st = ExecState::with_threads_isa(threads, isa);
    qm.run_quant_state(quant_input(qm, img), &mut st).unwrap()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("fatm_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn assert_same_logits(a: &QTensor, b: &QTensor, tag: &str) {
    assert_eq!(a.shape, b.shape, "{tag}: shape");
    assert_eq!(a.qp, b.qp, "{tag}: output qparams");
    assert_eq!(a.data, b.data, "{tag}: quantized logits");
}

#[test]
fn roundtrip_bit_exact_across_isa_and_threads() {
    for name in ["tiny_cnn", "mnas_mini_10"] {
        let qm = build(name);
        let dir = tmp_dir("rt");
        let path = dir.join(format!("{name}.fatm"));
        let etag = artifact::save(&qm, &path, Isa::detect()).unwrap();
        assert_eq!(artifact::peek_etag(&path).unwrap(), etag);

        for force_heap in [false, true] {
            let (loaded, rep) = artifact::load(
                &path,
                LoadOptions { force_heap, ..Default::default() },
            )
            .unwrap();
            assert_eq!(rep.etag, etag, "{name}: etag");
            if force_heap {
                assert!(!rep.mapped, "{name}: force_heap must not mmap");
            }
            assert_eq!(loaded.param_bytes, qm.param_bytes, "{name}");
            assert_eq!(loaded.graph.name, qm.graph.name, "{name}");
            for isa in Isa::available() {
                for threads in [1, 8] {
                    for img in 0..2 {
                        let want = logits(&qm, img, threads, isa);
                        let got = logits(&loaded, img, threads, isa);
                        assert_same_logits(
                            &want,
                            &got,
                            &format!(
                                "{name} heap={force_heap} {} t{threads} \
                                 img{img}",
                                isa.name()
                            ),
                        );
                    }
                }
            }
        }
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn serialization_is_deterministic_and_etag_tracks_content() {
    let qm = build("tiny_cnn");
    let b1 = artifact::to_bytes(&qm, Isa::Scalar);
    let b2 = artifact::to_bytes(&qm, Isa::Scalar);
    assert_eq!(b1, b2, "same model must serialize byte-identically");
    // A different packing tag is different content → different etag.
    let b3 = artifact::to_bytes(&qm, Isa::Avx2);
    assert_ne!(b1, b3);
    let (_, r1) = artifact::load_from_bytes(b1, LoadOptions::default()).unwrap();
    let (_, r3) = artifact::load_from_bytes(b3, LoadOptions::default()).unwrap();
    assert_ne!(r1.etag, r3.etag);
    // A different model is different content too.
    let other = artifact::to_bytes(&build("mnas_mini_10"), Isa::Scalar);
    let (_, r_other) =
        artifact::load_from_bytes(other, LoadOptions::default()).unwrap();
    assert_ne!(r1.etag, r_other.etag);
}

#[test]
fn foreign_isa_tag_repacks_to_identical_logits() {
    let qm = build("tiny_cnn");
    // Tag the panels as packed for avx2, then load pinned to scalar:
    // the loader must notice the mismatch and repack.
    let bytes = artifact::to_bytes(&qm, Isa::Avx2);
    let (loaded, rep) = artifact::load_from_bytes(
        bytes,
        LoadOptions { isa: Some(Isa::Scalar), ..Default::default() },
    )
    .unwrap();
    assert_eq!(rep.file_isa, Isa::Avx2);
    assert_eq!(rep.host_isa, Isa::Scalar);
    assert!(rep.repacked, "isa mismatch must repack");
    for threads in [1, 8] {
        let want = logits(&qm, 0, threads, Isa::Scalar);
        let got = logits(&loaded, 0, threads, Isa::Scalar);
        assert_same_logits(&want, &got, &format!("repacked t{threads}"));
    }
    // Matching tag: no repack, slabs stay windows into the buffer.
    let bytes = artifact::to_bytes(&qm, Isa::Scalar);
    let (_, rep) = artifact::load_from_bytes(
        bytes,
        LoadOptions { isa: Some(Isa::Scalar), ..Default::default() },
    )
    .unwrap();
    assert!(!rep.repacked, "matching isa must not repack");
}

#[test]
fn tuned_blocking_table_round_trips_and_serves_bit_exact() {
    use fat::int8::engine::QNode;
    use fat::int8::{Blocking, PackedWeights};

    let mut qm = build("mnas_mini_10");
    // Stamp a deterministic non-default schedule per packed layer —
    // same mechanics as `tune::tune_model`, minus its timing
    // nondeterminism. The first pick changes the strip width, so the
    // writer must persist nr=32 panels and the loader must parameterize
    // panel geometry from the table.
    let picks = [
        Blocking { kc: 256, nr: 32, mr: 8, grain: 4 },
        Blocking { kc: 64, nr: 16, mr: 2, grain: 1 },
    ];
    let mut stamped = 0;
    for p in &mut qm.plan.params {
        let QNode::Layer(l) = p else { continue };
        let Some(pw) = &l.packed else { continue };
        let (k, n) = (pw.k, pw.n);
        let bk = picks[stamped % picks.len()];
        stamped += 1;
        l.blocking = bk;
        l.packed = Some(PackedWeights::pack_with(&l.w_q, k, n, bk.nr));
    }
    assert!(stamped >= 2, "model must have packed layers to stamp");

    let dir = tmp_dir("tuned");
    let path = dir.join("tuned.fatm");
    artifact::save(&qm, &path, Isa::detect()).unwrap();
    let (loaded, rep) =
        artifact::load(&path, LoadOptions::default()).unwrap();
    assert!(!rep.repacked, "matching isa tag must keep tuned panels");
    // The per-layer table survives the round trip exactly…
    assert_eq!(loaded.blocking_summary(), qm.blocking_summary());
    // …and the tuned schedules serve bit-exact logits everywhere.
    for isa in Isa::available() {
        for threads in [1, 8] {
            let want = logits(&qm, 0, threads, isa);
            let got = logits(&loaded, 0, threads, isa);
            assert_same_logits(
                &want,
                &got,
                &format!("tuned {} t{threads}", isa.name()),
            );
        }
    }

    // A foreign packing-ISA tag resets the schedule to defaults: the
    // table was chosen on the packing host, so it falls back together
    // with the repack — results still bit-exact, only the schedule moves.
    let bytes = artifact::to_bytes(&qm, Isa::Avx2);
    let (fallback, rep) = artifact::load_from_bytes(
        bytes,
        LoadOptions { isa: Some(Isa::Scalar), ..Default::default() },
    )
    .unwrap();
    assert!(rep.repacked);
    for (bk, _) in fallback.blocking_summary() {
        assert_eq!(bk, Blocking::default(), "foreign host keeps defaults");
    }
    let want = logits(&qm, 1, 2, Isa::Scalar);
    let got = logits(&fallback, 1, 2, Isa::Scalar);
    assert_same_logits(&want, &got, "foreign-host fallback");
    let _ = std::fs::remove_file(&path);
}

/// [`build`] under explicit export knobs (pow2 / int4).
fn build_knobbed(name: &str, knobs: QuantKnobs) -> QModel {
    let (g, s, w) = builtin::load(name).unwrap();
    let mut st = CalibStats::new(s.sites.len());
    for (i, site) in s.sites.iter().enumerate() {
        let lo = if site.unsigned { 0.0 } else { -2.0 - 0.1 * i as f32 };
        st.site_minmax[i].update(lo, 2.5 + 0.2 * i as f32);
    }
    st.batches = 1;
    let tr = Trained::identity(&g, QuantMode::SymVector, s.sites.len());
    build_qmodel_with(&g, &w, &s, &st, QuantMode::SymVector, &tr, knobs)
        .unwrap()
}

#[test]
fn pow2_int4_artifacts_round_trip_with_shift_and_nibble_panels() {
    for (knobs, tag) in [
        (QuantKnobs { pow2: true, w_bits: 8 }, "pow2"),
        (QuantKnobs { pow2: false, w_bits: 4 }, "w4"),
        (QuantKnobs { pow2: true, w_bits: 4 }, "pow2_w4"),
    ] {
        let qm = build_knobbed("mnas_mini_10", knobs);
        let summary = qm.epilogue_summary();
        let (shift, mul, int4, _) = summary;
        if knobs.pow2 {
            assert!(shift > 0 && mul == 0, "{tag}: {summary:?}");
        }
        if knobs.w_bits == 4 {
            assert!(int4 > 0, "{tag}: {summary:?}");
        }

        // PLAN v3 round trip: the shift tables and nibble panels survive
        // byte-exactly and serve bit-identical logits everywhere.
        let bytes = artifact::to_bytes(&qm, Isa::detect());
        let (loaded, rep) =
            artifact::load_from_bytes(bytes, LoadOptions::default()).unwrap();
        assert!(!rep.repacked, "{tag}");
        assert_eq!(loaded.epilogue_summary(), summary, "{tag}");
        for isa in Isa::available() {
            for threads in [1, 8] {
                let want = logits(&qm, 0, threads, isa);
                let got = logits(&loaded, 0, threads, isa);
                assert_same_logits(
                    &want,
                    &got,
                    &format!("{tag} {} t{threads}", isa.name()),
                );
            }
        }

        // Foreign packing-ISA tag: the repack must preserve the panel
        // bit width (an int4 model must not silently widen to int8).
        let bytes = artifact::to_bytes(&qm, Isa::Avx2);
        let (repacked, rep) = artifact::load_from_bytes(
            bytes,
            LoadOptions { isa: Some(Isa::Scalar), ..Default::default() },
        )
        .unwrap();
        assert!(rep.repacked, "{tag}");
        assert_eq!(repacked.epilogue_summary(), summary, "{tag}: repack");
        let want = logits(&qm, 1, 2, Isa::Scalar);
        let got = logits(&repacked, 1, 2, Isa::Scalar);
        assert_same_logits(&want, &got, &format!("{tag}: repacked"));
    }
}

#[test]
fn older_plan_versions_cannot_carry_v3_features_but_default_models_can() {
    // A default-knob model still writes genuine v1/v2 byte streams that
    // load in this build (the back-compat contract the debug_asserts in
    // the writer protect: only shift-free, 8-bit models are eligible).
    let qm = build("tiny_cnn");
    for version in [1u32, 2] {
        let bytes = artifact::to_bytes_versioned(&qm, Isa::detect(), version);
        let (loaded, _) =
            artifact::load_from_bytes(bytes, LoadOptions::default()).unwrap();
        assert_eq!(loaded.epilogue_summary(), qm.epilogue_summary());
        let want = logits(&qm, 0, 2, Isa::detect());
        let got = logits(&loaded, 0, 2, Isa::detect());
        assert_same_logits(&want, &got, &format!("v{version}"));
    }
}

#[test]
fn plan_v1_artifacts_still_load_with_default_blockings() {
    use fat::int8::Blocking;

    let qm = build("tiny_cnn");
    // A genuine v1 byte stream (no per-layer blocking table) must keep
    // loading in this build, with every layer on the default schedule.
    let v1 = artifact::to_bytes_versioned(&qm, Isa::detect(), 1);
    let v2 = artifact::to_bytes(&qm, Isa::detect());
    assert_ne!(v1, v2, "v2 adds the blocking table to the PLAN bytes");
    let (loaded, _) =
        artifact::load_from_bytes(v1, LoadOptions::default()).unwrap();
    for (bk, _) in loaded.blocking_summary() {
        assert_eq!(bk, Blocking::default());
    }
    for threads in [1, 8] {
        let want = logits(&qm, 0, threads, Isa::detect());
        let got = logits(&loaded, 0, threads, Isa::detect());
        assert_same_logits(&want, &got, &format!("v1 t{threads}"));
    }
}

#[test]
fn tampered_artifact_is_rejected() {
    let qm = build("tiny_cnn");
    let bytes = artifact::to_bytes(&qm, Isa::Scalar);
    // Sanity: the pristine bytes load.
    artifact::load_from_bytes(bytes.clone(), LoadOptions::default()).unwrap();
    // A flip anywhere must fail (magic, size, digest or digest-covered
    // content).
    for at in [0, 9, 17, 30, bytes.len() / 2, bytes.len() - 1] {
        let mut m = bytes.clone();
        m[at] ^= 0x40;
        assert!(
            artifact::load_from_bytes(m, LoadOptions::default()).is_err(),
            "flip at {at} accepted"
        );
    }
    // Truncation must fail.
    let cut = bytes[..bytes.len() - 1].to_vec();
    assert!(artifact::load_from_bytes(cut, LoadOptions::default()).is_err());
}

/// One raw keep-alive-less HTTP GET against a live loopback server.
fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8(buf).unwrap();
    let (head, body) = text.split_once("\r\n\r\n").unwrap();
    assert!(head.starts_with("HTTP/1.1 200"), "{path}: {head}");
    body.to_string()
}

#[test]
fn registry_serves_artifact_with_etag_over_live_server() {
    let qm = build("tiny_cnn");
    let dir = tmp_dir("srv");
    let path = dir.join("tiny_cnn.fatm");
    let etag = artifact::save(&qm, &path, Isa::detect()).unwrap();

    let registry = ModelRegistry::new();
    let (reg_name, rep) = registry
        .load_artifact(&path, EngineOptions::threads(2))
        .unwrap();
    assert_eq!(reg_name, "tiny_cnn");
    assert_eq!(rep.etag, etag);
    let meta = registry.meta("tiny_cnn").unwrap();
    assert_eq!(meta.etag.as_deref(), Some(etag.as_str()));
    assert_eq!(meta.loads, 1);

    let server = Server::bind(
        "127.0.0.1:0",
        registry.clone(),
        ServerOptions::default(),
    )
    .unwrap();
    let addr = server.local_addr();

    // GET /models lists the artifact with its provenance.
    let j = Json::parse(&http_get(addr, "/models")).unwrap();
    let models = j.req("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 1);
    let m = &models[0];
    assert_eq!(m.req("name").unwrap().as_str().unwrap(), "tiny_cnn");
    assert_eq!(m.req("etag").unwrap().as_str().unwrap(), etag);
    assert_eq!(m.usize_or("loads", 0), 1);
    assert!(m.usize_or("loaded_at", 0) > 0);

    // /stats carries the etag in the per-model block too.
    let st = Json::parse(&http_get(addr, "/stats")).unwrap();
    let pm = st
        .get("models")
        .and_then(|ms| ms.get("tiny_cnn"))
        .expect("per-model stats");
    assert_eq!(pm.req("etag").unwrap().as_str().unwrap(), etag);
    // ...and the epilogue/weight-panel census: a default-knob model is
    // all multiplier epilogues over int8 panels.
    let ep = pm.get("epilogues").expect("epilogues census in /stats");
    assert_eq!(ep.usize_or("shift", 99), 0);
    assert!(ep.usize_or("multiplier", 0) > 0);
    let wb = pm.get("weight_bits").expect("weight_bits census in /stats");
    assert_eq!(wb.usize_or("int4", 99), 0);
    assert!(wb.usize_or("int8", 0) > 0);
    // ...and the conv-path + scratch census: every packed conv-like
    // layer of a default export carries the fused bit (tiny_cnn's lone
    // staged layer is the unpacked dwconv), and no request has run yet,
    // so the per-worker scratch high-water marks are zero.
    let cp = pm.get("conv_path").expect("conv_path census in /stats");
    assert!(cp.usize_or("fused", 0) > 0);
    assert_eq!(cp.usize_or("staged", 99), 1);
    let sb = pm.get("scratch_bytes").expect("scratch census in /stats");
    assert_eq!(sb.usize_or("patches", 99), 0);
    assert_eq!(sb.usize_or("acc", 99), 0);
    assert_eq!(sb.usize_or("arena", 99), 0);

    // The artifact-loaded model answers inference over the wire,
    // bit-exact with the in-memory reference interpreter.
    let want = qm.run_quant_ref(quant_input(&qm, 0)).unwrap().dequantize();
    let mut c = fat::net::HttpClient::connect(addr, "tiny_cnn").unwrap();
    let sh = input_shape(&qm);
    let per_img: usize = sh.iter().product();
    let px: Vec<u8> =
        (0..per_img).map(|i| ((i * 37 + 5) % 256) as u8).collect();
    let got = c.infer_one(&px).unwrap();
    assert_eq!(got.len(), want.len());
    for i in 0..got.len() {
        assert_eq!(got[i].to_bits(), want[i].to_bits(), "logit {i}");
    }
    drop(c);

    // The file is untouched since load_artifact statted it, so sync_dir
    // settles it on the (mtime, len) pre-check without a header read.
    let sr = registry.sync_dir(&dir, EngineOptions::threads(2)).unwrap();
    assert_eq!(sr.loaded, Vec::<String>::new());
    assert_eq!(sr.unchanged, 1);
    assert_eq!(sr.stat_skipped, 1);
    // Re-saving identical content bumps the mtime: the pre-check misses,
    // the etag peek says unchanged, and the fresh signature is recorded
    // so the pass after that skips the peek again.
    std::thread::sleep(std::time::Duration::from_millis(20));
    artifact::save(&qm, &path, Isa::detect()).unwrap();
    let sr = registry.sync_dir(&dir, EngineOptions::threads(2)).unwrap();
    assert_eq!(sr.loaded, Vec::<String>::new());
    assert_eq!(sr.unchanged, 1);
    let sr = registry.sync_dir(&dir, EngineOptions::threads(2)).unwrap();
    assert_eq!(sr.unchanged, 1);
    assert_eq!(sr.stat_skipped, 1);
    // A different artifact at the same path is a changed etag → reload;
    // the old name the file used to serve under is retired.
    let other = build("mnas_mini_10");
    artifact::save(&other, &path, Isa::detect()).unwrap();
    let sr = registry.sync_dir(&dir, EngineOptions::threads(2)).unwrap();
    assert_eq!(sr.loaded, vec!["mnas_mini_10".to_string()]);
    assert_eq!(sr.removed, vec!["tiny_cnn".to_string()]);
    assert!(registry.get("tiny_cnn").is_none());
    assert_eq!(registry.meta("mnas_mini_10").unwrap().loads, 1);
    // Deleting the file retires the entry on the next sync.
    std::fs::remove_file(&path).unwrap();
    let sr = registry.sync_dir(&dir, EngineOptions::threads(2)).unwrap();
    assert_eq!(sr.removed, vec!["mnas_mini_10".to_string()]);
    assert!(registry.get("mnas_mini_10").is_none());

    server.drain(std::time::Duration::from_secs(2));
}
