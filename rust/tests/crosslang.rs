//! Cross-language golden tests: Rust substrate vs Python-produced goldens
//! (`artifacts/goldens/*.fatw`). These prove the two sides of the system
//! agree bit-for-bit (dataset) or to f32 rounding (quant math, BN fold).
//!
//! Skipped gracefully when artifacts have not been built yet.

use fat::data::synth;
use fat::model::{fatw, GraphDef};
use fat::quant::{fold, scale::QParams};

fn goldens_dir() -> Option<std::path::PathBuf> {
    let d = fat::artifacts_dir().join("goldens");
    d.exists().then_some(d)
}

macro_rules! need {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => {
                eprintln!("SKIP: artifacts not built");
                return;
            }
        }
    };
}

#[test]
fn dataset_bit_exact_with_python() {
    let dir = need!(goldens_dir());
    let g = fatw::read_fatw(dir.join("dataset.fatw")).unwrap();
    let (img, labels) = synth::generate(synth::SEED_TRAIN, &[0, 1, 2, 3]);
    let want = g["train4_x"].as_f32().unwrap();
    assert_eq!(img.len(), want.len());
    for (i, (a, b)) in img.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "pixel {i}: {a} vs {b}");
    }
    let want_y = g["train4_y"].as_i32().unwrap();
    assert_eq!(labels, want_y);

    let (val, _) = synth::generate(synth::SEED_VAL, &[0, 1, 2, 3]);
    let want_v = g["val4_x"].as_f32().unwrap();
    for (a, b) in val.iter().zip(want_v) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn fake_quant_matches_python_oracle() {
    let dir = need!(goldens_dir());
    let g = fatw::read_fatw(dir.join("fq.fatw")).unwrap();
    let x = g["x"].as_f32().unwrap();

    // symmetric signed, T = 1.7
    let qp = QParams::symmetric_signed(1.7);
    let want = g["sym_127_y"].as_f32().unwrap();
    for (i, (&xv, &wv)) in x.iter().zip(want).enumerate() {
        let got = qp.fake_quant(xv);
        assert!(
            (got - wv).abs() <= 1e-6,
            "sym i={i} x={xv} got={got} want={wv}"
        );
    }

    // symmetric unsigned, T = 2.1, over |x|
    let qp = QParams::symmetric_unsigned(2.1);
    let want = g["sym_u8_y"].as_f32().unwrap();
    for (&xv, &wv) in x.iter().zip(want) {
        let got = qp.fake_quant(xv.abs());
        assert!((got - wv).abs() <= 1e-6, "unsigned x={xv}");
    }

    // per-channel: columns of (64, 32) use per-channel T
    let t_ch = g["t_ch"].as_f32().unwrap();
    let want = g["sym_ch_y"].as_f32().unwrap();
    for (i, &xv) in x.iter().enumerate() {
        let qp = QParams::symmetric_signed(t_ch[i % 32]);
        let got = qp.fake_quant(xv);
        assert!((got - want[i]).abs() <= 1e-6, "ch i={i}");
    }

    // asymmetric [-0.9, -0.9+3.3]: python ref has a float (un-nudged)
    // zero point, so compare against the raw affine formula.
    let want = g["asym_y"].as_f32().unwrap();
    let (left, width) = (-0.9f32, 3.3f32);
    let s = 255.0 / width;
    for (&xv, &wv) in x.iter().zip(want) {
        let got = ((xv - left) * s).round_ties_even().clamp(0.0, 255.0) / s
            + left;
        assert!((got - wv).abs() <= 1e-5, "asym x={xv} {got} vs {wv}");
    }
}

#[test]
fn bn_fold_matches_python() {
    let artifacts = fat::artifacts_dir();
    if !artifacts.join("models").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    for model in fat::model::ModelStore::list(&artifacts).unwrap() {
        let store = fat::model::ModelStore::open(&artifacts, &model).unwrap();
        let raw_graph: GraphDef = store.graph().unwrap();
        let raw = store.raw_weights().unwrap();
        let golden = store.folded_weights_golden().unwrap();
        let folded = fold::fold_bn(&raw_graph, &raw).unwrap();
        assert_eq!(folded.len(), golden.len(), "{model}: key sets differ");
        for (k, t) in &folded {
            let want = &golden[k];
            assert_eq!(t.shape, want.shape, "{model}:{k}");
            let a = t.as_f32().unwrap();
            let b = want.as_f32().unwrap();
            for i in 0..a.len() {
                assert!(
                    (a[i] - b[i]).abs() <= 1e-5 * b[i].abs().max(1.0),
                    "{model}:{k}[{i}] {} vs {}",
                    a[i],
                    b[i]
                );
            }
        }
    }
}

#[test]
fn sites_match_rust_enumeration() {
    let artifacts = fat::artifacts_dir();
    if !artifacts.join("models").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    for model in fat::model::ModelStore::list(&artifacts).unwrap() {
        let store = fat::model::ModelStore::open(&artifacts, &model).unwrap();
        let folded = store.folded_graph().unwrap();
        let sites_py = store.sites().unwrap();
        let sites_rs = folded.sites();
        assert_eq!(sites_rs.len(), sites_py.sites.len(), "{model}");
        for (rs, py) in sites_rs.iter().zip(&sites_py.sites) {
            assert_eq!(rs.0, py.id, "{model}");
            assert_eq!(rs.1, py.unsigned, "{model}:{}", py.id);
        }
        // weight order must agree too (artifact marshalling contract)
        assert_eq!(folded.folded_weight_order(), sites_py.weight_order);
    }
}
