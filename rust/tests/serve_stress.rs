//! Concurrent serving stress (DESIGN.md §9): {2, 8} OS-thread clients
//! hammer one cloned [`Int8Engine`] with interleaved `infer` and
//! `infer_batch` calls, across micro-batching on/off and worker counts
//! {1, 8}, and every response must be **bit-exact** with the
//! scalar/serial reference interpreter `run_quant_ref` — coalescing
//! requests into micro-batches may change scheduling, never bytes.
//! (CI additionally re-runs this whole file under `FAT_THREADS=1` and
//! `FAT_THREADS=8`; the env knob is process-wide, so the in-process
//! sweep here pins counts through `EngineOptions::threads` instead.)

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use fat::int8::batcher::BatchOptions;
use fat::int8::serve::{drive_with, EngineOptions, InferClient, Int8Engine};
use fat::int8::{QModel, QTensor};
use fat::model::store::{Site, SitesJson};
use fat::model::{GraphDef, Op};
use fat::net::client::parse_logits_json;
use fat::net::{FrameClient, HttpClient, ModelRegistry, Server, ServerOptions};
use fat::quant::calibrate::CalibStats;
use fat::quant::export::{build_qmodel, QuantMode, Trained};
use fat::tensor::Tensor;
use fat::util::json::Json;
use fat::util::prop;

/// Residual branch + DWS chain + dense head (the `session_equiv.rs`
/// geometry): odd channels, odd input size, stride-2 dwconv, both relu
/// flavours — small enough that a debug-build stress run stays fast.
const GRAPH: &str = r#"{
  "name": "stress", "num_classes": 4,
  "nodes": [
    {"id": "input", "op": "input", "inputs": [], "shape": [9, 9, 3]},
    {"id": "c0", "op": "conv", "inputs": ["input"], "k": 3, "stride": 1, "cin": 3, "cout": 5, "bias": true},
    {"id": "r0", "op": "relu6", "inputs": ["c0"]},
    {"id": "dw", "op": "dwconv", "inputs": ["r0"], "k": 3, "stride": 2, "ch": 5, "bias": true},
    {"id": "r1", "op": "relu", "inputs": ["dw"]},
    {"id": "c1", "op": "conv", "inputs": ["r1"], "k": 1, "stride": 1, "cin": 5, "cout": 7, "bias": true},
    {"id": "c2", "op": "conv", "inputs": ["r1"], "k": 1, "stride": 1, "cin": 5, "cout": 7, "bias": true},
    {"id": "ad", "op": "add", "inputs": ["c1", "c2"]},
    {"id": "g", "op": "gap", "inputs": ["ad"]},
    {"id": "d", "op": "dense", "inputs": ["g"], "cin": 7, "cout": 4, "bias": true}
  ]}"#;

fn model() -> QModel {
    let g = GraphDef::from_json(GRAPH).unwrap();
    let mut w = BTreeMap::new();
    let mut seed = 300u64;
    for n in g.conv_like() {
        let (wlen, cout) = match n.op {
            Op::Conv => (n.k * n.k * n.cin * n.cout, n.cout),
            Op::DwConv => (n.k * n.k * n.ch, n.ch),
            Op::Dense => (n.cin * n.cout, n.cout),
            _ => unreachable!(),
        };
        w.insert(
            format!("{}.w", n.id),
            Tensor::f32(vec![wlen], prop::f32s(seed, wlen, -0.6, 0.6)),
        );
        w.insert(
            format!("{}.b", n.id),
            Tensor::f32(vec![cout], prop::f32s(seed + 1, cout, -0.2, 0.2)),
        );
        seed += 2;
    }
    let s = SitesJson {
        sites: g
            .sites()
            .into_iter()
            .map(|(id, unsigned)| Site { id, unsigned })
            .collect(),
        channel_stats: vec![],
        weight_order: g.folded_weight_order(),
        val_acc_fp_pretrain: -1.0,
    };
    let mut st = CalibStats::new(s.sites.len());
    for (i, site) in s.sites.iter().enumerate() {
        let lo = if site.unsigned { 0.0 } else { -2.5 - 0.1 * i as f32 };
        st.site_minmax[i].update(lo, 3.0 + 0.2 * i as f32);
    }
    st.batches = 1;
    let tr = Trained::identity(&g, QuantMode::SymVector, s.sites.len());
    build_qmodel(&g, &w, &s, &st, QuantMode::SymVector, &tr).unwrap()
}

const H: usize = 9;
const W: usize = 9;
const C: usize = 3;
const PER_IMG: usize = H * W * C;
/// Distinct synthetic images the clients draw from.
const IMAGES: usize = 6;

fn pixels(img: usize) -> Vec<u8> {
    (0..PER_IMG)
        .map(|i| ((i * 29 + img * 83 + 7) % 256) as u8)
        .collect()
}

/// Oracle logits row for image `img`, from the reference interpreter.
fn oracle_rows(qm: &QModel) -> Vec<Vec<f32>> {
    (0..IMAGES)
        .map(|img| {
            let x: Vec<f32> =
                pixels(img).iter().map(|&p| p as f32 / 255.0).collect();
            let q = QTensor::quantize(vec![1, H, W, C], &x, qm.input_qp);
            qm.run_quant_ref(q).unwrap().dequantize()
        })
        .collect()
}

fn assert_row_eq(got: &[f32], want: &[f32], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}");
    for i in 0..got.len() {
        assert_eq!(
            got[i].to_bits(),
            want[i].to_bits(),
            "{tag} logit {i}: {} != {}",
            got[i],
            want[i]
        );
    }
}

/// The tentpole assertion: interleaved `infer` / `infer_batch` traffic
/// from concurrent clients stays bit-exact with `run_quant_ref`, for
/// batching on/off × engine workers {1, 8} × clients {2, 8}.
fn hammer(engine: &Int8Engine, oracle: &[Vec<f32>], clients: usize, tag: &str) {
    let reqs_per_client = 6usize;
    std::thread::scope(|s| {
        for c in 0..clients {
            let eng = engine.clone();
            let tag = format!("{tag} client {c}");
            s.spawn(move || {
                for r in 0..reqs_per_client {
                    if (c + r) % 2 == 0 {
                        // single raw-image request
                        let img = (c * 5 + r) % IMAGES;
                        let got = eng.infer(&pixels(img)).unwrap();
                        assert_row_eq(
                            &got,
                            &oracle[img],
                            &format!("{tag} req {r} infer[{img}]"),
                        );
                    } else {
                        // small float batch: rows must match per-image
                        // oracles (images are independent)
                        let n = 2 + (c + r) % 2; // 2 or 3 images
                        let imgs: Vec<usize> =
                            (0..n).map(|j| (c + r + 3 * j) % IMAGES).collect();
                        let mut x = Vec::with_capacity(n * PER_IMG);
                        for &img in &imgs {
                            x.extend(
                                pixels(img)
                                    .iter()
                                    .map(|&p| p as f32 / 255.0),
                            );
                        }
                        let t = Tensor::f32(vec![n, H, W, C], x);
                        let out = eng.infer_batch(&t).unwrap();
                        assert_eq!(out.shape[0], n, "{tag} req {r}");
                        let classes = out.shape[1];
                        let of = out.as_f32().unwrap();
                        for (j, &img) in imgs.iter().enumerate() {
                            assert_row_eq(
                                &of[j * classes..(j + 1) * classes],
                                &oracle[img],
                                &format!("{tag} req {r} batch row {j}[{img}]"),
                            );
                        }
                    }
                }
            });
        }
    });
}

#[test]
fn concurrent_traffic_bit_exact_batching_off_and_on() {
    let qm = model();
    let oracle = oracle_rows(&qm);
    for threads in [1usize, 8] {
        for batch in [None, Some(BatchOptions::default())] {
            let opts = EngineOptions {
                threads: Some(threads),
                batch,
            };
            let engine = Int8Engine::new(qm.clone(), opts);
            for clients in [2usize, 8] {
                hammer(
                    &engine,
                    &oracle,
                    clients,
                    &format!(
                        "t={threads} batch={} clients={clients}",
                        batch.is_some()
                    ),
                );
            }
            if batch.is_some() {
                let (req, bat, rows) =
                    engine.batcher_stats().expect("batcher enabled");
                assert!(req > 0 && bat > 0 && rows >= bat);
                assert!(
                    bat <= req,
                    "batches ({bat}) cannot exceed requests ({req})"
                );
            } else {
                assert!(engine.batcher_stats().is_none());
            }
        }
    }
}

#[test]
fn batched_singleton_pays_only_the_deadline() {
    // A lone request on an otherwise idle batched engine must execute
    // after max_wait and stay bit-exact.
    let qm = model();
    let oracle = oracle_rows(&qm);
    let engine = Int8Engine::new(
        qm,
        EngineOptions::threads(2).with_batch(BatchOptions {
            max_batch: 8,
            max_wait_us: 100,
        }),
    );
    for img in 0..IMAGES {
        let got = engine.infer(&pixels(img)).unwrap();
        assert_row_eq(&got, &oracle[img], &format!("singleton img {img}"));
    }
    let (req, bat, rows) = engine.batcher_stats().unwrap();
    assert_eq!(req, IMAGES as u64);
    assert_eq!(rows, IMAGES as u64);
    assert_eq!(bat, IMAGES as u64, "idle singletons each run alone");
}

#[test]
fn default_options_leave_batching_off() {
    let qm = model();
    let engine = Int8Engine::new(qm, EngineOptions::default());
    assert!(engine.batcher_stats().is_none());
    // oversized and non-input-shaped batches run the direct path even
    // on a batched engine (and stay correct)
    let qm2 = model();
    let oracle = oracle_rows(&qm2);
    let batched = Int8Engine::new(
        qm2,
        EngineOptions::threads(2).with_batch(BatchOptions {
            max_batch: 2,
            max_wait_us: 100,
        }),
    );
    let n = 5; // > max_batch: bypasses the batcher
    let imgs: Vec<usize> = (0..n).map(|j| j % IMAGES).collect();
    let mut x = Vec::with_capacity(n * PER_IMG);
    for &img in &imgs {
        x.extend(pixels(img).iter().map(|&p| p as f32 / 255.0));
    }
    let out = batched.infer_batch(&Tensor::f32(vec![n, H, W, C], x)).unwrap();
    let classes = out.shape[1];
    let of = out.as_f32().unwrap();
    for (j, &img) in imgs.iter().enumerate() {
        assert_row_eq(
            &of[j * classes..(j + 1) * classes],
            &oracle[img],
            &format!("oversized batch row {j}"),
        );
    }
    let (req, bat, _rows) = batched.batcher_stats().unwrap();
    assert_eq!((req, bat), (0, 0), "oversized batch must bypass the batcher");
}

// ---------------------------------------------------------------------
// Socket front-end: fault injection and backpressure (DESIGN.md §10)
// ---------------------------------------------------------------------

/// Boot a loopback server over the given named engines.
fn boot(models: &[(&str, Int8Engine)], opts: ServerOptions) -> Server {
    let registry = ModelRegistry::new();
    for (name, engine) in models {
        registry.insert(name, engine.clone());
    }
    Server::bind("127.0.0.1:0", registry, opts).unwrap()
}

/// The same driver + bit-exactness oracle that hammers the in-process
/// engine runs over live sockets, alternating HTTP and frame clients,
/// against batched and unbatched endpoints of one server — and the
/// `/stats` counters must reconcile exactly with the client tallies.
#[test]
fn socket_transport_bit_exact_and_stats_reconcile() {
    let qm = model();
    let oracle = oracle_rows(&qm);
    let unbat = Int8Engine::new(qm.clone(), EngineOptions::threads(2));
    let bat = Int8Engine::new(
        qm,
        EngineOptions::threads(2).with_batch(BatchOptions {
            max_batch: 4,
            max_wait_us: 200,
        }),
    );
    let server =
        boot(&[("unbat", unbat), ("bat", bat)], ServerOptions::default());
    let addr = server.local_addr();
    let per_client = 4usize;
    let mut total = 0u64;
    let oracle = &oracle;
    for name in ["unbat", "bat"] {
        for clients in [2usize, 8] {
            let report = drive_with(
                |c| -> anyhow::Result<Box<dyn InferClient + Send>> {
                    // even clients speak HTTP, odd ones the frame wire
                    if c % 2 == 0 {
                        Ok(Box::new(HttpClient::connect(addr, name)?))
                    } else {
                        Ok(Box::new(FrameClient::connect(addr, name)?))
                    }
                },
                clients,
                per_client,
                |c| pixels(c % IMAGES),
                |c| Some(oracle[c % IMAGES].clone()),
            )
            .unwrap();
            assert_eq!(report.requests, clients * per_client);
            total += report.requests as u64;
        }
    }
    let st = server.stats();
    assert_eq!(st.completed, total, "every request completed");
    assert_eq!(st.admitted, total);
    assert_eq!((st.rejected, st.failed, st.malformed), (0, 0, 0));
    assert_eq!(st.in_flight, 0);
    // the client-visible /stats document tells the same story
    let mut c = HttpClient::connect(addr, "unbat").unwrap();
    let j = Json::parse(&c.stats().unwrap()).unwrap();
    assert_eq!(j.usize_or("completed", 0) as u64, total);
    assert!(
        j.get("models").and_then(|m| m.get("bat")).is_some(),
        "per-model stats for every registered model"
    );
    drop(c);
    server.drain(Duration::from_secs(2));
    assert_eq!(server.stats().open_conns, 0);
}

/// Slow-loris attackers dribble a partial request head and stall. The
/// read deadline must cut them off (408 or clean close, counted as
/// timeouts) while concurrent well-behaved clients stay bit-exact.
#[test]
fn slow_loris_deadline_fires_and_good_clients_unaffected() {
    let qm = model();
    let oracle = oracle_rows(&qm);
    let engine = Int8Engine::new(qm, EngineOptions::threads(2));
    let opts = ServerOptions {
        read_timeout: Duration::from_millis(300),
        ..ServerOptions::default()
    };
    let server = boot(&[("stress", engine)], opts);
    let addr = server.local_addr();
    let oracle = &oracle;
    std::thread::scope(|s| {
        for _ in 0..2 {
            s.spawn(move || {
                let sock = TcpStream::connect(addr).unwrap();
                sock.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
                let mut sock = sock;
                sock.write_all(b"POST /v1/models/stress/infer HT").unwrap();
                // ...and never another byte. The server must answer or
                // hang up on its own; a hang fails the 5s read below.
                let mut buf = Vec::new();
                sock.read_to_end(&mut buf).unwrap();
                if !buf.is_empty() {
                    let text = String::from_utf8_lossy(&buf);
                    assert!(text.starts_with("HTTP/1.1 408"), "{text}");
                }
            });
        }
        for c in 0..4usize {
            s.spawn(move || {
                let mut client = HttpClient::connect(addr, "stress").unwrap();
                for r in 0..4usize {
                    let img = (c + r) % IMAGES;
                    let got = client.infer_one(&pixels(img)).unwrap();
                    assert_row_eq(
                        &got,
                        &oracle[img],
                        &format!("good client {c} req {r}"),
                    );
                }
            });
        }
    });
    assert!(
        server.stats().timeouts >= 2,
        "both loris connections must hit the read deadline"
    );
    server.drain(Duration::from_secs(2));
    assert_eq!(server.stats().open_conns, 0);
}

/// A client that vanishes mid-body is observed as a disconnect, its
/// worker is reclaimed, and the server keeps serving bit-exact.
#[test]
fn mid_request_disconnect_is_counted_and_survivable() {
    let qm = model();
    let oracle = oracle_rows(&qm);
    let engine = Int8Engine::new(qm, EngineOptions::threads(2));
    let server = boot(&[("stress", engine)], ServerOptions::default());
    let addr = server.local_addr();
    {
        let mut sock = TcpStream::connect(addr).unwrap();
        let head = format!(
            "POST /v1/models/stress/infer HTTP/1.1\r\n\
             Content-Length: {PER_IMG}\r\n\r\n"
        );
        sock.write_all(head.as_bytes()).unwrap();
        sock.write_all(&pixels(0)[..10]).unwrap();
        // drop: FIN with a partial request buffered server-side
    }
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while server.stats().disconnects == 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "mid-request disconnect never observed"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    let mut client = HttpClient::connect(addr, "stress").unwrap();
    let got = client.infer_one(&pixels(2)).unwrap();
    assert_row_eq(&got, &oracle[2], "after disconnect");
    drop(client);
    server.drain(Duration::from_secs(2));
    assert_eq!(server.stats().open_conns, 0);
}

/// A half-closed socket (client shuts down its write side after the
/// request) still gets the complete response before the server closes.
#[test]
fn half_closed_socket_still_gets_full_response() {
    let qm = model();
    let oracle = oracle_rows(&qm);
    let engine = Int8Engine::new(qm, EngineOptions::threads(2));
    let server = boot(&[("stress", engine)], ServerOptions::default());
    let addr = server.local_addr();
    let mut sock = TcpStream::connect(addr).unwrap();
    sock.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let px = pixels(1);
    let head = format!(
        "POST /v1/models/stress/infer HTTP/1.1\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n",
        px.len()
    );
    sock.write_all(head.as_bytes()).unwrap();
    sock.write_all(&px).unwrap();
    sock.shutdown(Shutdown::Write).unwrap();
    let mut buf = Vec::new();
    sock.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8_lossy(&buf);
    assert!(text.starts_with("HTTP/1.1 200"), "{text}");
    let body = text.split("\r\n\r\n").nth(1).expect("has body");
    let got = parse_logits_json(body).unwrap();
    assert_row_eq(&got, &oracle[1], "half-closed");
    server.drain(Duration::from_secs(2));
}

/// Over-admission: with `max_inflight = 1` and a slow batched engine,
/// a burst of clients must be shed with 429s; everyone admitted stays
/// bit-exact, and the server counters reconcile exactly with the
/// client-side tallies.
#[test]
fn overload_answers_429_and_counters_reconcile() {
    let qm = model();
    let oracle = oracle_rows(&qm);
    // one worker + a long micro-batch deadline: each admitted request
    // holds the single in-flight slot for >= 150ms
    let engine = Int8Engine::new(
        qm,
        EngineOptions::threads(1).with_batch(BatchOptions {
            max_batch: 64,
            max_wait_us: 150_000,
        }),
    );
    let opts = ServerOptions {
        max_inflight: 1,
        ..ServerOptions::default()
    };
    let server = boot(&[("stress", engine)], opts);
    let addr = server.local_addr();
    let clients = 8usize;
    let per_client = 2usize;
    let ok = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let barrier = std::sync::Barrier::new(clients);
    let (oracle, ok, rejected, barrier) = (&oracle, &ok, &rejected, &barrier);
    std::thread::scope(|s| {
        for c in 0..clients {
            s.spawn(move || {
                let mut client = HttpClient::connect(addr, "stress").unwrap();
                barrier.wait();
                for r in 0..per_client {
                    let img = (c + r) % IMAGES;
                    let (status, body) =
                        client.infer_status(&pixels(img)).unwrap();
                    match status {
                        200 => {
                            let got = parse_logits_json(
                                std::str::from_utf8(&body).unwrap(),
                            )
                            .unwrap();
                            assert_row_eq(
                                &got,
                                &oracle[img],
                                &format!("admitted {c}/{r}"),
                            );
                            ok.fetch_add(1, Ordering::Relaxed);
                        }
                        429 => {
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        other => panic!("unexpected status {other}"),
                    }
                }
            });
        }
    });
    let (ok, rejected) =
        (ok.load(Ordering::Relaxed), rejected.load(Ordering::Relaxed));
    assert_eq!(ok + rejected, (clients * per_client) as u64);
    assert!(ok > 0, "someone must get through");
    assert!(rejected > 0, "max_inflight=1 under 8 clients must shed load");
    let st = server.stats();
    assert_eq!(st.completed, ok, "server completed == client 200s");
    assert_eq!(st.admitted, ok);
    assert_eq!(st.rejected, rejected, "server rejected == client 429s");
    assert_eq!(st.failed, 0);
    assert_eq!(st.in_flight, 0);
    server.drain(Duration::from_secs(2));
}

/// Drain finishes in-flight work, closes every connection and gives the
/// port back; post-drain connects get no service.
#[test]
fn drain_stops_accepting_and_closes_the_port() {
    let qm = model();
    let engine = Int8Engine::new(qm, EngineOptions::threads(1));
    let server = boot(&[("stress", engine)], ServerOptions::default());
    let addr = server.local_addr();
    let mut c = HttpClient::connect(addr, "stress").unwrap();
    assert!(c.stats().unwrap().starts_with('{'), "alive before drain");
    drop(c);
    server.drain(Duration::from_secs(2));
    assert!(server.is_draining());
    let st = server.stats();
    assert_eq!((st.open_conns, st.in_flight), (0, 0));
    // The listener is gone: a fresh connect fails outright, or — if the
    // OS queued it in the backlog before the close — yields no service.
    match TcpStream::connect_timeout(&addr, Duration::from_millis(500)) {
        Err(_) => {}
        Ok(mut sock) => {
            sock.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
            let _ = sock.write_all(
                b"GET /stats HTTP/1.1\r\nContent-Length: 0\r\n\r\n",
            );
            let mut buf = Vec::new();
            if sock.read_to_end(&mut buf).is_ok() {
                assert!(buf.is_empty(), "drained server served a request");
            }
        }
    }
}
