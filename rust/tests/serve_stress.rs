//! Concurrent serving stress (DESIGN.md §9): {2, 8} OS-thread clients
//! hammer one cloned [`Int8Engine`] with interleaved `infer` and
//! `infer_batch` calls, across micro-batching on/off and worker counts
//! {1, 8}, and every response must be **bit-exact** with the
//! scalar/serial reference interpreter `run_quant_ref` — coalescing
//! requests into micro-batches may change scheduling, never bytes.
//! (CI additionally re-runs this whole file under `FAT_THREADS=1` and
//! `FAT_THREADS=8`; the env knob is process-wide, so the in-process
//! sweep here pins counts through `EngineOptions::threads` instead.)

use std::collections::BTreeMap;

use fat::int8::batcher::BatchOptions;
use fat::int8::serve::{EngineOptions, Int8Engine};
use fat::int8::{QModel, QTensor};
use fat::model::store::{Site, SitesJson};
use fat::model::{GraphDef, Op};
use fat::quant::calibrate::CalibStats;
use fat::quant::export::{build_qmodel, QuantMode, Trained};
use fat::tensor::Tensor;
use fat::util::prop;

/// Residual branch + DWS chain + dense head (the `session_equiv.rs`
/// geometry): odd channels, odd input size, stride-2 dwconv, both relu
/// flavours — small enough that a debug-build stress run stays fast.
const GRAPH: &str = r#"{
  "name": "stress", "num_classes": 4,
  "nodes": [
    {"id": "input", "op": "input", "inputs": [], "shape": [9, 9, 3]},
    {"id": "c0", "op": "conv", "inputs": ["input"], "k": 3, "stride": 1, "cin": 3, "cout": 5, "bias": true},
    {"id": "r0", "op": "relu6", "inputs": ["c0"]},
    {"id": "dw", "op": "dwconv", "inputs": ["r0"], "k": 3, "stride": 2, "ch": 5, "bias": true},
    {"id": "r1", "op": "relu", "inputs": ["dw"]},
    {"id": "c1", "op": "conv", "inputs": ["r1"], "k": 1, "stride": 1, "cin": 5, "cout": 7, "bias": true},
    {"id": "c2", "op": "conv", "inputs": ["r1"], "k": 1, "stride": 1, "cin": 5, "cout": 7, "bias": true},
    {"id": "ad", "op": "add", "inputs": ["c1", "c2"]},
    {"id": "g", "op": "gap", "inputs": ["ad"]},
    {"id": "d", "op": "dense", "inputs": ["g"], "cin": 7, "cout": 4, "bias": true}
  ]}"#;

fn model() -> QModel {
    let g = GraphDef::from_json(GRAPH).unwrap();
    let mut w = BTreeMap::new();
    let mut seed = 300u64;
    for n in g.conv_like() {
        let (wlen, cout) = match n.op {
            Op::Conv => (n.k * n.k * n.cin * n.cout, n.cout),
            Op::DwConv => (n.k * n.k * n.ch, n.ch),
            Op::Dense => (n.cin * n.cout, n.cout),
            _ => unreachable!(),
        };
        w.insert(
            format!("{}.w", n.id),
            Tensor::f32(vec![wlen], prop::f32s(seed, wlen, -0.6, 0.6)),
        );
        w.insert(
            format!("{}.b", n.id),
            Tensor::f32(vec![cout], prop::f32s(seed + 1, cout, -0.2, 0.2)),
        );
        seed += 2;
    }
    let s = SitesJson {
        sites: g
            .sites()
            .into_iter()
            .map(|(id, unsigned)| Site { id, unsigned })
            .collect(),
        channel_stats: vec![],
        weight_order: g.folded_weight_order(),
        val_acc_fp_pretrain: -1.0,
    };
    let mut st = CalibStats::new(s.sites.len());
    for (i, site) in s.sites.iter().enumerate() {
        let lo = if site.unsigned { 0.0 } else { -2.5 - 0.1 * i as f32 };
        st.site_minmax[i].update(lo, 3.0 + 0.2 * i as f32);
    }
    st.batches = 1;
    let tr = Trained::identity(&g, QuantMode::SymVector, s.sites.len());
    build_qmodel(&g, &w, &s, &st, QuantMode::SymVector, &tr).unwrap()
}

const H: usize = 9;
const W: usize = 9;
const C: usize = 3;
const PER_IMG: usize = H * W * C;
/// Distinct synthetic images the clients draw from.
const IMAGES: usize = 6;

fn pixels(img: usize) -> Vec<u8> {
    (0..PER_IMG)
        .map(|i| ((i * 29 + img * 83 + 7) % 256) as u8)
        .collect()
}

/// Oracle logits row for image `img`, from the reference interpreter.
fn oracle_rows(qm: &QModel) -> Vec<Vec<f32>> {
    (0..IMAGES)
        .map(|img| {
            let x: Vec<f32> =
                pixels(img).iter().map(|&p| p as f32 / 255.0).collect();
            let q = QTensor::quantize(vec![1, H, W, C], &x, qm.input_qp);
            qm.run_quant_ref(q).unwrap().dequantize()
        })
        .collect()
}

fn assert_row_eq(got: &[f32], want: &[f32], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}");
    for i in 0..got.len() {
        assert_eq!(
            got[i].to_bits(),
            want[i].to_bits(),
            "{tag} logit {i}: {} != {}",
            got[i],
            want[i]
        );
    }
}

/// The tentpole assertion: interleaved `infer` / `infer_batch` traffic
/// from concurrent clients stays bit-exact with `run_quant_ref`, for
/// batching on/off × engine workers {1, 8} × clients {2, 8}.
fn hammer(engine: &Int8Engine, oracle: &[Vec<f32>], clients: usize, tag: &str) {
    let reqs_per_client = 6usize;
    std::thread::scope(|s| {
        for c in 0..clients {
            let eng = engine.clone();
            let tag = format!("{tag} client {c}");
            s.spawn(move || {
                for r in 0..reqs_per_client {
                    if (c + r) % 2 == 0 {
                        // single raw-image request
                        let img = (c * 5 + r) % IMAGES;
                        let got = eng.infer(&pixels(img)).unwrap();
                        assert_row_eq(
                            &got,
                            &oracle[img],
                            &format!("{tag} req {r} infer[{img}]"),
                        );
                    } else {
                        // small float batch: rows must match per-image
                        // oracles (images are independent)
                        let n = 2 + (c + r) % 2; // 2 or 3 images
                        let imgs: Vec<usize> =
                            (0..n).map(|j| (c + r + 3 * j) % IMAGES).collect();
                        let mut x = Vec::with_capacity(n * PER_IMG);
                        for &img in &imgs {
                            x.extend(
                                pixels(img)
                                    .iter()
                                    .map(|&p| p as f32 / 255.0),
                            );
                        }
                        let t = Tensor::f32(vec![n, H, W, C], x);
                        let out = eng.infer_batch(&t).unwrap();
                        assert_eq!(out.shape[0], n, "{tag} req {r}");
                        let classes = out.shape[1];
                        let of = out.as_f32().unwrap();
                        for (j, &img) in imgs.iter().enumerate() {
                            assert_row_eq(
                                &of[j * classes..(j + 1) * classes],
                                &oracle[img],
                                &format!("{tag} req {r} batch row {j}[{img}]"),
                            );
                        }
                    }
                }
            });
        }
    });
}

#[test]
fn concurrent_traffic_bit_exact_batching_off_and_on() {
    let qm = model();
    let oracle = oracle_rows(&qm);
    for threads in [1usize, 8] {
        for batch in [None, Some(BatchOptions::default())] {
            let opts = EngineOptions {
                threads: Some(threads),
                batch,
            };
            let engine = Int8Engine::new(qm.clone(), opts);
            for clients in [2usize, 8] {
                hammer(
                    &engine,
                    &oracle,
                    clients,
                    &format!(
                        "t={threads} batch={} clients={clients}",
                        batch.is_some()
                    ),
                );
            }
            if batch.is_some() {
                let (req, bat, rows) =
                    engine.batcher_stats().expect("batcher enabled");
                assert!(req > 0 && bat > 0 && rows >= bat);
                assert!(
                    bat <= req,
                    "batches ({bat}) cannot exceed requests ({req})"
                );
            } else {
                assert!(engine.batcher_stats().is_none());
            }
        }
    }
}

#[test]
fn batched_singleton_pays_only_the_deadline() {
    // A lone request on an otherwise idle batched engine must execute
    // after max_wait and stay bit-exact.
    let qm = model();
    let oracle = oracle_rows(&qm);
    let engine = Int8Engine::new(
        qm,
        EngineOptions::threads(2).with_batch(BatchOptions {
            max_batch: 8,
            max_wait_us: 100,
        }),
    );
    for img in 0..IMAGES {
        let got = engine.infer(&pixels(img)).unwrap();
        assert_row_eq(&got, &oracle[img], &format!("singleton img {img}"));
    }
    let (req, bat, rows) = engine.batcher_stats().unwrap();
    assert_eq!(req, IMAGES as u64);
    assert_eq!(rows, IMAGES as u64);
    assert_eq!(bat, IMAGES as u64, "idle singletons each run alone");
}

#[test]
fn default_options_leave_batching_off() {
    let qm = model();
    let engine = Int8Engine::new(qm, EngineOptions::default());
    assert!(engine.batcher_stats().is_none());
    // oversized and non-input-shaped batches run the direct path even
    // on a batched engine (and stay correct)
    let qm2 = model();
    let oracle = oracle_rows(&qm2);
    let batched = Int8Engine::new(
        qm2,
        EngineOptions::threads(2).with_batch(BatchOptions {
            max_batch: 2,
            max_wait_us: 100,
        }),
    );
    let n = 5; // > max_batch: bypasses the batcher
    let imgs: Vec<usize> = (0..n).map(|j| j % IMAGES).collect();
    let mut x = Vec::with_capacity(n * PER_IMG);
    for &img in &imgs {
        x.extend(pixels(img).iter().map(|&p| p as f32 / 255.0));
    }
    let out = batched.infer_batch(&Tensor::f32(vec![n, H, W, C], x)).unwrap();
    let classes = out.shape[1];
    let of = out.as_f32().unwrap();
    for (j, &img) in imgs.iter().enumerate() {
        assert_row_eq(
            &of[j * classes..(j + 1) * classes],
            &oracle[img],
            &format!("oversized batch row {j}"),
        );
    }
    let (req, bat, _rows) = batched.batcher_stats().unwrap();
    assert_eq!((req, bat), (0, 0), "oversized batch must bypass the batcher");
}
