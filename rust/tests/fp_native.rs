//! Artifact-free tests of the native backend: the staged session API
//! running calibrate → fine-tune → export → int8 serving entirely on
//! the native FP32 executor (`fat::fp`) over builtin models. This is
//! the offline twin of `rust/tests/pipeline.rs` and the test for the
//! ISSUE-3 acceptance criterion: the full pipeline completes with no
//! `artifacts/` directory present, and the native fine-tune loss
//! decreases over an epoch of synth data.

use std::sync::Arc;

use fat::coordinator::finetune::FinetuneOpts;
use fat::int8::serve::EngineOptions;
use fat::model::builtin;
use fat::quant::session::{CalibOpts, QuantSession, QuantSpec};
use fat::quant::QuantMode;
use fat::runtime::{Registry, Runtime};

/// A session over a builtin model rooted at a directory that does not
/// exist — proving no artifact file is ever touched.
fn native_session(model: &str) -> QuantSession {
    let reg = Arc::new(Registry::new(Arc::new(Runtime::cpu().unwrap())));
    let session =
        QuantSession::open(reg, "definitely-no-artifacts-here", model)
            .unwrap();
    assert_eq!(session.core().backend_name(), "native");
    session
}

fn fast_opts(max_steps: usize) -> FinetuneOpts {
    FinetuneOpts {
        epochs: 1,
        stride: 10,
        lr: 2e-2,
        cycle: 0,
        max_steps,
        seed: 0xFA7,
    }
}

#[test]
fn unknown_model_error_names_builtins() {
    let reg = Arc::new(Registry::new(Arc::new(Runtime::cpu().unwrap())));
    let err = QuantSession::open(reg, "definitely-no-artifacts-here", "nope")
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("tiny_cnn"), "{msg}");
    assert!(msg.contains("make artifacts"), "{msg}");
}

#[test]
fn native_pipeline_end_to_end_without_artifacts() {
    let session = native_session("tiny_cnn");
    let spec = QuantSpec::parse("asym_vector", "max").unwrap();

    // calibrate → identity-quantize → export → serve → infer
    let cal = session.calibrate(CalibOpts::images(25)).unwrap();
    assert_eq!(
        cal.stats().site_minmax.len(),
        session.core().sites.sites.len()
    );
    let fp = cal.fp_accuracy(100).unwrap();
    assert!((0.0..=1.0).contains(&fp));
    let th = cal.identity(&spec).unwrap();
    let q = th.quant_accuracy(100).unwrap();
    assert!((0.0..=1.0).contains(&q));
    let engine = th.serve(EngineOptions::threads(2)).unwrap();
    assert!(engine.param_bytes() > 100);
    let (x, _) = fat::data::loader::batch(
        fat::data::Split::Val,
        &(0..10).collect::<Vec<_>>(),
    );
    let logits = engine.infer_batch(&x).unwrap();
    assert_eq!(logits.shape, vec![10, 10]);
    assert!(logits.as_f32().unwrap().iter().all(|v| v.is_finite()));

    // int8 engine tracks the native fake-quant forward
    let a8 = fat::coordinator::evaluate::int8_accuracy(&engine, 100).unwrap();
    assert!(
        (q - a8).abs() <= 0.15,
        "int8 {a8} vs native fake-quant {q}"
    );
}

#[test]
fn native_finetune_loss_decreases_over_an_epoch() {
    // The paper's scenario: max-calibrated thresholds inflated by rare
    // outliers, which threshold training then shrinks (α < 1). A
    // freshly max-calibrated tame net already sits near α* ≈ 1, so to
    // get a *robust* decrease signal we inflate the calibrated ranges
    // 4x (exactly what a heavy-tailed activation would do to the max
    // calibrator) and let the trainer recover the tight thresholds.
    let session = native_session("tiny_cnn");
    let cal0 = session.calibrate(CalibOpts::images(25)).unwrap();
    let mut inflated = cal0.stats().clone();
    for mm in inflated.site_minmax.iter_mut() {
        mm.min *= 4.0;
        mm.max *= 4.0;
    }
    let cal = session.assume_calibrated(inflated, CalibOpts::images(25));
    for mode in [QuantMode::SymScalar, QuantMode::AsymScalar] {
        let spec = QuantSpec::from_mode(mode);
        let th = cal.finetune(&spec, &fast_opts(20), |_, _, _| {}).unwrap();
        let losses = th.losses();
        assert_eq!(losses.len(), 20, "{mode:?}");
        assert!(
            losses.iter().all(|l| l.is_finite() && *l >= 0.0),
            "{mode:?}: non-finite loss"
        );
        // RMSE distillation must reduce the quantization error: compare
        // the first and last thirds of the loss curve (robust to
        // per-batch noise).
        let third = losses.len() / 3;
        let head: f32 = losses[..third].iter().sum::<f32>() / third as f32;
        let tail: f32 =
            losses[losses.len() - third..].iter().sum::<f32>() / third as f32;
        assert!(
            tail < head,
            "{mode:?}: loss did not decrease ({head:.5} -> {tail:.5}; {losses:?})"
        );
        // and the threshold scales actually moved below 1 (the analytic
        // gradient pushes α down toward the un-inflated ranges)
        let tr = th.thresholds().trained();
        let scales = if mode.asym() { &tr.act_ar } else { &tr.act_a };
        let mean: f32 = scales.iter().sum::<f32>() / scales.len() as f32;
        assert!(
            mean < 0.97,
            "{mode:?}: threshold scales did not shrink (mean α = {mean})"
        );
        // fine-tuned thresholds still export + serve
        let engine = th.serve(EngineOptions::threads(2)).unwrap();
        let a8 =
            fat::coordinator::evaluate::int8_accuracy(&engine, 50).unwrap();
        assert!((0.0..=1.0).contains(&a8), "{mode:?}");
    }
}

#[test]
fn native_finetune_runs_from_fresh_calibration_too() {
    // With honestly-calibrated ranges the optimum sits near α ≈ 1, so
    // only sanity properties are asserted here (the decrease signal is
    // pinned by the inflated-range test above).
    let session = native_session("tiny_cnn");
    let cal = session.calibrate(CalibOpts::images(25)).unwrap();
    let spec = QuantSpec::from_mode(QuantMode::SymScalar);
    let th = cal.finetune(&spec, &fast_opts(8), |_, _, _| {}).unwrap();
    let losses = th.losses();
    assert_eq!(losses.len(), 8);
    assert!(losses.iter().all(|l| l.is_finite() && *l >= 0.0));
    assert!(th.quant_accuracy(50).is_ok());
}

#[test]
fn pow2_int4_spec_flows_through_the_whole_session() {
    // `_pow2`/`_w4` knobs parsed off the mode string must survive the
    // staged API end to end: fine-tune trains against the knob'd
    // student, the fake-quant accuracy uses it, and the export carries
    // shift tables + int4 panels into the engine.
    let session = native_session("tiny_cnn");
    let cal = session.calibrate(CalibOpts::images(25)).unwrap();
    let spec = QuantSpec::parse("sym_vector_pow2_w4", "max").unwrap();
    let th = cal.finetune(&spec, &fast_opts(6), |_, _, _| {}).unwrap();
    assert_eq!(th.losses().len(), 6);
    assert!(th.losses().iter().all(|l| l.is_finite() && *l >= 0.0));
    let q = th.quant_accuracy(50).unwrap();
    assert!((0.0..=1.0).contains(&q));

    let qm = th.export().unwrap();
    let (shift, mul, int4, int8) = qm.epilogue_summary();
    assert!(shift > 0, "pow2 export produced no shift-only layers");
    assert_eq!(mul, 0, "pow2 export left a multiplier epilogue behind");
    assert!(int4 > 0, "w4 export packed no int4 panels");
    let _ = int8; // depthwise layers stay unpacked

    // and it still serves
    let engine = th.serve(EngineOptions::threads(2)).unwrap();
    let a8 = fat::coordinator::evaluate::int8_accuracy(&engine, 50).unwrap();
    assert!((0.0..=1.0).contains(&a8));
    // int4 + shift-only quantization is coarser but must stay sane on
    // the tame builtin net
    assert!(
        (q - a8).abs() <= 0.25,
        "int8 engine {a8} vs fake-quant student {q}"
    );
}

#[test]
fn native_calibrators_flow_through_hist_pass() {
    let session = native_session("tiny_cnn");
    let cal = session.calibrate(CalibOpts::images(25)).unwrap();
    let max_spec = QuantSpec::parse("sym_vector", "max").unwrap();
    let p_spec = QuantSpec::parse("sym_vector", "p999").unwrap();
    let th_max = cal.identity(&max_spec).unwrap();
    let th_p = cal.identity(&p_spec).unwrap();
    // the percentile calibrator shrinks at least one site range
    let shrunk = th_max
        .stats()
        .site_minmax
        .iter()
        .zip(&th_p.stats().site_minmax)
        .any(|(a, b)| b.max < a.max || b.min > a.min);
    assert!(shrunk, "p999 calibrator shrank no range");
    // and the shrunk model still evaluates + exports
    let acc = th_p.quant_accuracy(50).unwrap();
    assert!((0.0..=1.0).contains(&acc));
    assert!(th_p.export().is_ok());
}

#[test]
fn dws_rescale_runs_natively_and_preserves_fp() {
    // mnas has dw→pw patterns; §3.3 rescaling must work off the native
    // channel stats and leave the FP32 function intact
    let session = native_session("mnas_mini_10");
    let before = session.fp_accuracy(50).unwrap();
    let cal = session.calibrate(CalibOpts::images(25)).unwrap();
    let cal = cal.dws_rescale().unwrap();
    assert!(!cal.rescale_reports().is_empty(), "no DWS patterns rescaled");
    let after = cal.fp_accuracy(50).unwrap();
    assert!(
        (before - after).abs() <= 0.02,
        "§3.3 rescale changed the FP32 function: {before} -> {after}"
    );
}

#[test]
fn from_parts_session_runs_custom_graph() {
    // a hand-built graph + weights, no model zoo involved at all
    // (input must be 32x32x3 — the SynthShapes calibration batches are)
    let g = fat::model::GraphDef::from_json(
        r#"{"name":"custom","num_classes":4,"nodes":[
         {"id":"input","op":"input","inputs":[],"shape":[32,32,3]},
         {"id":"c","op":"conv","inputs":["input"],"k":3,"stride":2,"cin":3,"cout":6,"bias":true},
         {"id":"r","op":"relu","inputs":["c"]},
         {"id":"g","op":"gap","inputs":["r"]},
         {"id":"d","op":"dense","inputs":["g"],"cin":6,"cout":4,"bias":true}]}"#,
    )
    .unwrap();
    let sites = builtin::sites_of(&g);
    let weights = builtin::init_weights(&g, 7);
    let session = QuantSession::from_parts(g, sites, weights);
    assert_eq!(session.core().backend_name(), "native");
    let cal = session.calibrate(CalibOpts::images(25)).unwrap();
    let th = cal.identity(&QuantSpec::default()).unwrap();
    let engine = th.serve(EngineOptions::threads(1)).unwrap();
    // raw-bytes single-image serving path on the custom head size
    let logits = engine.infer(&[7u8; 32 * 32 * 3]).unwrap();
    assert_eq!(logits.len(), 4);
}

#[test]
fn every_builtin_compiles_and_calibrates_one_batch() {
    for name in builtin::names() {
        let (g, sites, w) = builtin::load(name).unwrap();
        let prog = fat::fp::FpProgram::compile(&g, &w, &sites, None).unwrap();
        // one tiny forward proves the plan executes for every topology
        let (x, _) =
            fat::data::loader::batch(fat::data::Split::Val, &[0, 1]);
        let y = prog.run_batch(&x, 2).unwrap();
        assert_eq!(y.shape, vec![2, 10], "{name}");
        assert!(
            y.as_f32().unwrap().iter().all(|v| v.is_finite()),
            "{name}: non-finite logits"
        );
    }
}
