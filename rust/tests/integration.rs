//! Runtime integration tests: AOT artifact load/compile/execute against
//! Python-recorded goldens, and marshalling-contract validation.
//!
//! These need `make artifacts`; they skip gracefully otherwise.

use std::sync::Arc;

use fat::coordinator::marshal::{build_inputs, Group};
use fat::model::{fatw, ModelStore};
use fat::runtime::{Registry, Runtime};
use fat::tensor::Tensor;

fn setup() -> Option<(Arc<Registry>, std::path::PathBuf)> {
    let artifacts = fat::artifacts_dir();
    if !artifacts.join("models/mobilenet_v2_mini").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    if !fat::runtime::pjrt_available() {
        eprintln!(
            "SKIP: no `pjrt` feature (these tests execute AOT artifacts; \
             the native backend is covered by fp_native.rs)"
        );
        return None;
    }
    let rt = Runtime::cpu().ok()?;
    Some((Arc::new(Registry::new(Arc::new(rt))), artifacts))
}

macro_rules! need {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => return,
        }
    };
}

#[test]
fn fp_forward_matches_python_logits() {
    let (reg, artifacts) = need!(setup());
    let model = "mobilenet_v2_mini";
    let store = ModelStore::open(&artifacts, model).unwrap();
    let golden =
        fatw::read_fatw(artifacts.join(format!("goldens/model_{model}.fatw")))
            .unwrap();
    let raw_graph = store.graph().unwrap();
    let weights =
        fat::quant::fold::fold_bn(&raw_graph, &store.raw_weights().unwrap())
            .unwrap();

    let art = reg.get(store.artifact_path("fp_forward")).unwrap();
    let x = golden["x"].clone();
    let inputs = build_inputs(
        &art.manifest,
        &[Group::Map(&weights), Group::Single(&x)],
    )
    .unwrap();
    let logits = art.execute(&inputs).unwrap().remove(0);
    let want = golden["fp_logits"].as_f32().unwrap();
    let got = logits.as_f32().unwrap();
    assert_eq!(got.len(), want.len());
    for i in 0..got.len() {
        assert!(
            (got[i] - want[i]).abs() <= 2e-3 * want[i].abs().max(1.0),
            "logit {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn calib_stats_match_python() {
    let (reg, artifacts) = need!(setup());
    let model = "mnas_mini_10";
    let store = ModelStore::open(&artifacts, model).unwrap();
    let golden =
        fatw::read_fatw(artifacts.join(format!("goldens/model_{model}.fatw")))
            .unwrap();
    let raw_graph = store.graph().unwrap();
    let weights =
        fat::quant::fold::fold_bn(&raw_graph, &store.raw_weights().unwrap())
            .unwrap();

    let art = reg.get(store.artifact_path("calib_stats")).unwrap();
    let x = golden["calib_x"].clone();
    let inputs = build_inputs(
        &art.manifest,
        &[Group::Map(&weights), Group::Single(&x)],
    )
    .unwrap();
    let outs = art.execute(&inputs).unwrap();
    let o = fat::coordinator::marshal::split_outputs(&art.manifest, outs)
        .unwrap();
    let got = o.singles[&0].as_f32().unwrap();
    let want = golden["site_minmax"].as_f32().unwrap();
    for i in 0..want.len() {
        assert!(
            (got[i] - want[i]).abs() <= 1e-3 * want[i].abs().max(1.0),
            "site stat {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn quant_fwd_matches_python_for_identity_alphas() {
    let (reg, artifacts) = need!(setup());
    for (model, mode) in
        [("mobilenet_v2_mini", "sym_scalar"), ("mnas_mini_13", "asym_vector")]
    {
        let store = ModelStore::open(&artifacts, model).unwrap();
        let golden = fatw::read_fatw(
            artifacts.join(format!("goldens/model_{model}.fatw")),
        )
        .unwrap();
        let raw_graph = store.graph().unwrap();
        let weights = fat::quant::fold::fold_bn(
            &raw_graph,
            &store.raw_weights().unwrap(),
        )
        .unwrap();

        let art = reg
            .get(store.artifact_path(&format!("quant_fwd_{mode}")))
            .unwrap();
        // identity trainables shaped from the train_step manifest
        let ts = reg
            .get(store.artifact_path(&format!("train_step_{mode}")))
            .unwrap();
        let tr = fat::coordinator::finetune::init_trainables(&ts);
        let act_t = golden["site_minmax"].clone();
        let x = golden["x"].clone();
        let inputs = build_inputs(
            &art.manifest,
            &[
                Group::Map(&weights),
                Group::Single(&act_t),
                Group::Map(&tr),
                Group::Single(&x),
            ],
        )
        .unwrap();
        let logits = art.execute(&inputs).unwrap().remove(0);
        let want = golden[&format!("quant_logits_{mode}")].as_f32().unwrap();
        let got = logits.as_f32().unwrap();
        // The Rust BN fold reproduces Python's weights to f32 rounding
        // (~1e-6 relative), but fake-quant *rounds* weights — a near-tie
        // flipping one int8 step shifts logits by up to ~0.1. Assert a
        // loose element-wise bound plus argmax agreement, the semantic
        // property downstream accuracy depends on.
        let (n, c) = (logits.shape[0], logits.shape[1]);
        let mut worst = 0f32;
        let mut agree = 0usize;
        for i in 0..n {
            let row_g = &got[i * c..(i + 1) * c];
            let row_w = &want[i * c..(i + 1) * c];
            for j in 0..c {
                worst = worst.max((row_g[j] - row_w[j]).abs());
            }
            let am = |r: &[f32]| {
                r.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0
            };
            if am(row_g) == am(row_w) {
                agree += 1;
            }
        }
        assert!(worst <= 0.25, "{model}/{mode}: worst logit diff {worst}");
        assert!(
            agree as f64 >= 0.93 * n as f64,
            "{model}/{mode}: argmax agreement {agree}/{n}"
        );
    }
}

#[test]
fn manifest_rejects_wrong_shapes() {
    let (reg, artifacts) = need!(setup());
    let store = ModelStore::open(&artifacts, "mobilenet_v2_mini").unwrap();
    let art = reg.get(store.artifact_path("fp_forward")).unwrap();
    let bad = vec![Tensor::zeros_f32(vec![1])];
    assert!(art.execute(&bad).is_err());
}

#[test]
fn registry_caches_compilations() {
    let (reg, artifacts) = need!(setup());
    let store = ModelStore::open(&artifacts, "mobilenet_v2_mini").unwrap();
    let before = reg.compiled_count();
    let a1 = reg.get(store.artifact_path("fp_forward")).unwrap();
    let a2 = reg.get(store.artifact_path("fp_forward")).unwrap();
    assert!(Arc::ptr_eq(&a1, &a2));
    assert_eq!(reg.compiled_count(), before + 1);
}
