//! Mutation/truncation fuzz battery over the `.fatm` parser
//! (DESIGN.md §11.3): the loader's contract under hostile input is that
//! it **returns an error** — it never panics, never over-allocates,
//! never accepts a corrupted artifact. Three attack families:
//!
//! 1. every truncated prefix of a valid artifact,
//! 2. every single-byte flip (the FNV digest must catch all of them),
//! 3. digest-fixed flips (the digest is recomputed after the mutation,
//!    so the structural/semantic validators are the only line of
//!    defense) and random byte soups — any `Ok`/`Err` outcome is fine,
//!    the property is "returns", and every accepted mutant must also
//!    *execute* without panicking,
//! 4. hostile PLAN-v2 blocking tables (digest-fixed): zero/huge/odd
//!    `kc`, misaligned or oversized `nr`, out-of-range `mr`/`grain`,
//!    and a valid-but-mismatched strip width — all must be rejected
//!    by `Blocking::validate`/panel-geometry checks before they can
//!    parameterize `gemm_packed`'s unchecked inner loops,
//! 5. hostile PLAN-v3 records (digest-fixed): unknown packed-panel
//!    bits tags, a bits tag contradicting the stored panel length, a
//!    claimed shift table on a multiplier model (and the reverse), and
//!    a shift table disagreeing with the requant pairs — the pow2
//!    cross-check and `from_packed_bits` geometry must reject all of
//!    them before the shift/int4 epilogues run,
//! 6. hostile PLAN-v4 fused bits (digest-fixed): non-boolean flag
//!    values, a fused bit claimed on a layer with no packed panel (the
//!    micro-tiles have nothing to run on), and the v3 back-compat
//!    default (fused follows the packed record) — all checked before
//!    `conv2d_fused` can dereference a missing panel.

use std::collections::BTreeMap;

use fat::artifact::{self, fnv1a64, LoadOptions};
use fat::int8::{QModel, QTensor};
use fat::model::builtin::sites_of;
use fat::model::GraphDef;
use fat::quant::calibrate::CalibStats;
use fat::quant::export::{
    build_qmodel_with, QuantKnobs, QuantMode, Trained,
};
use fat::tensor::Tensor;
use fat::util::prop;

/// Small conv → gap → dense model: exercises packed panels, col sums
/// and every section of the container while keeping the byte-flip
/// sweep (one load per byte) fast.
const GRAPH: &str = r#"{
  "name": "fuzz", "num_classes": 3,
  "nodes": [
    {"id": "input", "op": "input", "inputs": [], "shape": [6, 6, 2]},
    {"id": "c", "op": "conv", "inputs": ["input"], "k": 3, "stride": 1,
     "cin": 2, "cout": 4, "bias": true},
    {"id": "g", "op": "gap", "inputs": ["c"]},
    {"id": "d", "op": "dense", "inputs": ["g"], "cin": 4, "cout": 3,
     "bias": true}
  ]}"#;

fn model() -> QModel {
    model_with(QuantKnobs::default())
}

fn model_with(knobs: QuantKnobs) -> QModel {
    let g = GraphDef::from_json(GRAPH).unwrap();
    let s = sites_of(&g);
    let mut w = BTreeMap::new();
    let mut seed = 77u64;
    for n in g.conv_like() {
        let (wlen, cout) = match n.op {
            fat::model::Op::Conv => (n.k * n.k * n.cin * n.cout, n.cout),
            fat::model::Op::Dense => (n.cin * n.cout, n.cout),
            _ => unreachable!("graph has no dwconv"),
        };
        w.insert(
            format!("{}.w", n.id),
            Tensor::f32(vec![wlen], prop::f32s(seed, wlen, -0.6, 0.6)),
        );
        w.insert(
            format!("{}.b", n.id),
            Tensor::f32(vec![cout], prop::f32s(seed + 1, cout, -0.2, 0.2)),
        );
        seed += 2;
    }
    let mut st = CalibStats::new(s.sites.len());
    for (i, site) in s.sites.iter().enumerate() {
        let lo = if site.unsigned { 0.0 } else { -2.0 - 0.1 * i as f32 };
        st.site_minmax[i].update(lo, 2.5 + 0.2 * i as f32);
    }
    st.batches = 1;
    let tr = Trained::identity(&g, QuantMode::SymVector, s.sites.len());
    build_qmodel_with(&g, &w, &s, &st, QuantMode::SymVector, &tr, knobs)
        .unwrap()
}

fn artifact_bytes() -> Vec<u8> {
    artifact::to_bytes(&model(), fat::int8::Isa::Scalar)
}

#[test]
fn every_truncated_prefix_errors() {
    let bytes = artifact_bytes();
    artifact::load_from_bytes(bytes.clone(), LoadOptions::default())
        .expect("pristine artifact loads");
    for cut in 0..bytes.len() {
        assert!(
            artifact::load_from_bytes(
                bytes[..cut].to_vec(),
                LoadOptions::default()
            )
            .is_err(),
            "prefix of {cut} bytes accepted"
        );
    }
    // Appended garbage breaks the declared file size.
    let mut extended = bytes;
    extended.push(0);
    assert!(artifact::load_from_bytes(extended, LoadOptions::default())
        .is_err());
}

#[test]
fn every_single_byte_flip_errors() {
    let bytes = artifact_bytes();
    // Flips in [0, 24) break magic/size/digest fields; flips in
    // [24, len) change the computed digest. Either way: rejected.
    for at in 0..bytes.len() {
        let mut m = bytes.clone();
        m[at] ^= 0x01;
        assert!(
            artifact::load_from_bytes(m, LoadOptions::default()).is_err(),
            "flip at byte {at} accepted"
        );
    }
}

/// Rewrite the stored digest so a mutated body passes the container
/// checks — the structural and semantic validators are then the only
/// defense.
fn fix_digest(bytes: &mut [u8]) {
    let d = fnv1a64(&bytes[24..]);
    bytes[16..24].copy_from_slice(&d.to_le_bytes());
}

#[test]
fn digest_fixed_flips_never_panic_and_accepted_mutants_execute() {
    let qm = model();
    let bytes = artifact_bytes();
    let input = {
        let x: Vec<f32> = (0..6 * 6 * 2)
            .map(|i| ((i * 37 + 5) % 256) as f32 / 255.0)
            .collect();
        QTensor::quantize(vec![1, 6, 6, 2], &x, qm.input_qp)
    };
    let mut accepted = 0usize;
    for at in 24..bytes.len() {
        let mut m = bytes.clone();
        m[at] ^= 0x40;
        fix_digest(&mut m);
        // The property is "returns": Ok (an inconsequential flip, e.g.
        // a weight byte) or a clean Err — never a panic.
        if let Ok((mutant, _)) =
            artifact::load_from_bytes(m, LoadOptions::default())
        {
            accepted += 1;
            // Anything the validator accepted must actually run: the
            // executor's unchecked hot paths rely on the loader's
            // geometry checks.
            let _ = mutant.run_quant(input.clone());
        }
    }
    // Sanity: the sweep exercised both validator rejections and
    // harmless mutations (weight bytes dominate the file).
    assert!(accepted > 0, "no mutant survived — sweep is vacuous");
    assert!(
        accepted < bytes.len() - 24,
        "every mutant survived — validators are vacuous"
    );
}

/// Overwrite every occurrence of the default blocking-table quad
/// (`kc=128, nr=64, mr=4, grain=1` as 4×u32 LE — 16 bytes distinctive
/// enough to only match the PLAN v2 table entries) with `quad`,
/// returning how many entries were patched.
fn patch_blockings(bytes: &mut [u8], quad: [u32; 4]) -> usize {
    let mut needle = [0u8; 16];
    for (j, v) in [128u32, 64, 4, 1].iter().enumerate() {
        needle[4 * j..4 * j + 4].copy_from_slice(&v.to_le_bytes());
    }
    let mut patched = 0;
    let mut i = 24;
    while i + 16 <= bytes.len() {
        if bytes[i..i + 16] == needle {
            for (j, v) in quad.iter().enumerate() {
                bytes[i + 4 * j..i + 4 * j + 4]
                    .copy_from_slice(&v.to_le_bytes());
            }
            patched += 1;
            i += 16;
        } else {
            i += 1;
        }
    }
    patched
}

#[test]
fn hostile_blocking_tables_are_rejected_before_the_kernels() {
    let bytes = artifact_bytes();
    artifact::load_from_bytes(bytes.clone(), LoadOptions::default())
        .expect("pristine artifact loads");
    // Sanity: the default quad is where we think it is (conv + dense =
    // at least two table entries).
    {
        let mut probe = bytes.clone();
        assert!(
            patch_blockings(&mut probe, [128, 64, 4, 1]) >= 2,
            "blocking-table needle not found — did the layout move?"
        );
    }
    for quad in [
        // kc: zero, odd, huge
        [0u32, 64, 4, 1],
        [3, 64, 4, 1],
        [1 << 20, 64, 4, 1],
        // nr: zero, misaligned, over the packed maximum
        [128, 0, 4, 1],
        [128, 8, 4, 1],
        [128, 63, 4, 1],
        [128, 128, 4, 1],
        // mr: zero, over MR_MAX
        [128, 64, 0, 1],
        [128, 64, 9, 1],
        // grain: zero, huge
        [128, 64, 4, 0],
        [128, 64, 4, 1 << 20],
        // everything hostile at once
        [u32::MAX; 4],
        // valid in isolation, but the strip width contradicts the
        // panels (packed at nr=64): the length check must catch it
        [128, 32, 4, 1],
    ] {
        let mut m = bytes.clone();
        assert!(patch_blockings(&mut m, quad) >= 2, "quad {quad:?}");
        fix_digest(&mut m);
        assert!(
            artifact::load_from_bytes(m, LoadOptions::default()).is_err(),
            "hostile blocking {quad:?} accepted"
        );
    }
}

/// Overwrite every occurrence of `needle` (a u32-LE sequence, scanned
/// past the header) with `repl`, returning the patch count. The v3
/// tests pick needles whose u32 runs are distinctive enough to only
/// match the intended PLAN records.
fn patch_u32_seq(bytes: &mut [u8], needle: &[u32], repl: &[u32]) -> usize {
    assert_eq!(needle.len(), repl.len());
    let nb: Vec<u8> = needle.iter().flat_map(|v| v.to_le_bytes()).collect();
    let rb: Vec<u8> = repl.iter().flat_map(|v| v.to_le_bytes()).collect();
    let mut patched = 0;
    let mut i = 24;
    while i + nb.len() <= bytes.len() {
        if bytes[i..i + nb.len()] == nb[..] {
            bytes[i..i + rb.len()].copy_from_slice(&rb);
            patched += 1;
            i += nb.len();
        } else {
            i += 1;
        }
    }
    patched
}

/// The fuzz model's two packed records as (k, n) — conv `c` packs
/// k·k·cin = 18 rows × cout 4, dense `d` packs 4 × 3. The v3 record is
/// `(present=1, k, n, bits)` as consecutive u32s.
const PACKED_KN: [(u32, u32); 2] = [(18, 4), (4, 3)];

#[test]
fn hostile_bits_tags_are_rejected() {
    let bytes = artifact_bytes();
    // Sanity: both packed records are where the needles expect.
    {
        let mut probe = bytes.clone();
        for (k, n) in PACKED_KN {
            assert_eq!(
                patch_u32_seq(&mut probe, &[1, k, n, 8], &[1, k, n, 8]),
                1,
                "packed record ({k}, {n}) not found — did the layout move?"
            );
        }
    }
    for hostile in [0u32, 1, 3, 5, 16, 255, u32::MAX] {
        for (k, n) in PACKED_KN {
            let mut m = bytes.clone();
            assert_eq!(
                patch_u32_seq(&mut m, &[1, k, n, 8], &[1, k, n, hostile]),
                1
            );
            fix_digest(&mut m);
            assert!(
                artifact::load_from_bytes(m, LoadOptions::default()).is_err(),
                "bits tag {hostile} on record ({k}, {n}) accepted"
            );
        }
    }
    // bits=4 is valid in isolation, but contradicts both the stored
    // int8 panel length and the full-range unpacked weights.
    for (k, n) in PACKED_KN {
        let mut m = bytes.clone();
        assert_eq!(patch_u32_seq(&mut m, &[1, k, n, 8], &[1, k, n, 4]), 1);
        fix_digest(&mut m);
        assert!(
            artifact::load_from_bytes(m, LoadOptions::default()).is_err(),
            "int8-length panel accepted as int4 on record ({k}, {n})"
        );
    }
    // ...and the reverse on a genuine int4 artifact: widening the tag
    // to 8 makes the nibble panel half the expected length.
    let b4 = artifact::to_bytes(
        &model_with(QuantKnobs { pow2: false, w_bits: 4 }),
        fat::int8::Isa::Scalar,
    );
    artifact::load_from_bytes(b4.clone(), LoadOptions::default())
        .expect("pristine int4 artifact loads");
    for (k, n) in PACKED_KN {
        let mut m = b4.clone();
        assert_eq!(patch_u32_seq(&mut m, &[1, k, n, 4], &[1, k, n, 8]), 1);
        fix_digest(&mut m);
        assert!(
            artifact::load_from_bytes(m, LoadOptions::default()).is_err(),
            "int4-length panel accepted as int8 on record ({k}, {n})"
        );
    }
}

#[test]
fn hostile_shift_records_are_rejected() {
    // The v3 layer record is `blocking quad, has_shift, ...`, so the
    // default-quad needle extended by the flag pins each layer entry.
    let bytes = artifact_bytes();
    {
        let mut probe = bytes.clone();
        assert!(
            patch_u32_seq(
                &mut probe,
                &[128, 64, 4, 1, 0],
                &[128, 64, 4, 1, 0]
            ) >= 2,
            "has_shift=0 needle not found — did the layout move?"
        );
    }
    // 1) Claim a shift table on a multiplier model: the reader then
    // misparses the following record — a clean error, never a panic.
    let mut m = bytes.clone();
    assert!(patch_u32_seq(&mut m, &[128, 64, 4, 1, 0], &[128, 64, 4, 1, 1]) >= 2);
    fix_digest(&mut m);
    assert!(
        artifact::load_from_bytes(m, LoadOptions::default()).is_err(),
        "claimed shift table on a multiplier model accepted"
    );
    // 2) Unknown present-flag values.
    for flag in [2u32, u32::MAX] {
        let mut m = bytes.clone();
        assert!(
            patch_u32_seq(&mut m, &[128, 64, 4, 1, 0], &[128, 64, 4, 1, flag])
                >= 2
        );
        fix_digest(&mut m);
        assert!(
            artifact::load_from_bytes(m, LoadOptions::default()).is_err(),
            "has_shift flag {flag} accepted"
        );
    }

    // 3) A genuine pow2 artifact: every conv-like requant pair is
    // exactly (1<<30, s-1). Nudging the multipliers leaves a shift
    // table that disagrees with the requant pairs — the load-time
    // pow2 cross-check must reject it.
    let pb = artifact::to_bytes(
        &model_with(QuantKnobs { pow2: true, w_bits: 8 }),
        fat::int8::Isa::Scalar,
    );
    artifact::load_from_bytes(pb.clone(), LoadOptions::default())
        .expect("pristine pow2 artifact loads");
    let mut m = pb.clone();
    let patched =
        patch_u32_seq(&mut m, &[1u32 << 30], &[(1u32 << 30) + 2]);
    assert!(patched >= 2, "no pow2 multiplier found in the PLAN bytes");
    fix_digest(&mut m);
    assert!(
        artifact::load_from_bytes(m, LoadOptions::default()).is_err(),
        "shift table disagreeing with the requant pairs accepted"
    );
    // 4) Dropping the flag on a pow2 artifact misaligns the reader —
    // again a clean error.
    let mut m = pb;
    assert!(patch_u32_seq(&mut m, &[128, 64, 4, 1, 1], &[128, 64, 4, 1, 0]) >= 2);
    fix_digest(&mut m);
    assert!(
        artifact::load_from_bytes(m, LoadOptions::default()).is_err(),
        "dropped shift flag on a pow2 model accepted"
    );
}

#[test]
fn hostile_fused_flags_are_rejected() {
    // The v4 layer record is `blocking quad, has_shift, fused, packed
    // present, ...`; on this multiplier model every conv-like layer is
    // packed, so the per-layer prefix is the distinctive
    // `[128, 64, 4, 1, 0, 1]` (default quad, no shift table, fused on).
    let bytes = artifact_bytes();
    {
        let mut probe = bytes.clone();
        assert!(
            patch_u32_seq(
                &mut probe,
                &[128, 64, 4, 1, 0, 1],
                &[128, 64, 4, 1, 0, 1]
            ) >= 2,
            "fused-flag needle not found — did the layout move?"
        );
    }
    // 1) Non-boolean flag values: the reader takes exactly {0, 1}.
    for flag in [2u32, 255, u32::MAX] {
        let mut m = bytes.clone();
        assert!(
            patch_u32_seq(
                &mut m,
                &[128, 64, 4, 1, 0, 1],
                &[128, 64, 4, 1, 0, flag]
            ) >= 2
        );
        fix_digest(&mut m);
        assert!(
            artifact::load_from_bytes(m, LoadOptions::default()).is_err(),
            "fused flag {flag} accepted"
        );
    }
    // 2) fused=1 with packed present=0 — a well-formed record (present=0
    // writes no panel geometry) whose fused bit has no panel to run on.
    // Serialize it for real so the *semantic* cross-check is what
    // rejects it, not a misaligned parse.
    let mut qm = model();
    let mut stripped = false;
    for p in &mut qm.plan.params {
        if let fat::int8::engine::QNode::Layer(l) = p {
            l.packed = None;
            l.fused = true;
            stripped = true;
            break;
        }
    }
    assert!(stripped, "no conv-like layer in the fuzz model");
    let contradicted = artifact::to_bytes(&qm, fat::int8::Isa::Scalar);
    let err = artifact::load_from_bytes(contradicted, LoadOptions::default())
        .expect_err("fused bit without a packed panel accepted");
    assert!(
        format!("{err:#}").contains("without a packed panel"),
        "wrong rejection: {err:#}"
    );
    // 3) Flipping a fused bit off (digest-fixed) is legal — staged
    // execution of a packed layer — and the mutant must still run.
    let mut m = bytes.clone();
    assert!(
        patch_u32_seq(&mut m, &[128, 64, 4, 1, 0, 1], &[128, 64, 4, 1, 0, 0])
            >= 2
    );
    fix_digest(&mut m);
    let (mutant, _) = artifact::load_from_bytes(m, LoadOptions::default())
        .expect("staged-bit mutant rejected");
    let x: Vec<f32> = (0..6 * 6 * 2).map(|i| (i % 7) as f32 / 7.0).collect();
    let q = QTensor::quantize(vec![1, 6, 6, 2], &x, mutant.input_qp);
    mutant.run_quant(q).expect("staged-bit mutant fails to run");
}

#[test]
fn plan_v3_bytes_default_the_fused_bit_from_the_packed_record() {
    // Back-compat: a genuine v3 artifact (no fused bit on the wire)
    // must load with fused following the packed record — on for packed
    // layers — and execute bit-exactly against the v4 form.
    let qm = model();
    let v3 = artifact::to_bytes_versioned(&qm, fat::int8::Isa::Scalar, 3);
    let v4 = artifact::to_bytes(&qm, fat::int8::Isa::Scalar);
    let (m3, _) = artifact::load_from_bytes(v3, LoadOptions::default())
        .expect("pristine v3 artifact loads");
    let (m4, _) = artifact::load_from_bytes(v4, LoadOptions::default())
        .expect("pristine v4 artifact loads");
    for p in &m3.plan.params {
        if let fat::int8::engine::QNode::Layer(l) = p {
            assert_eq!(
                l.fused,
                l.packed.is_some(),
                "v3 fused default out of sync with the packed record"
            );
        }
    }
    let x: Vec<f32> = (0..6 * 6 * 2).map(|i| (i % 5) as f32 / 5.0).collect();
    let q3 = QTensor::quantize(vec![1, 6, 6, 2], &x, m3.input_qp);
    let q4 = QTensor::quantize(vec![1, 6, 6, 2], &x, m4.input_qp);
    let y3 = m3.run_quant(q3).unwrap();
    let y4 = m4.run_quant(q4).unwrap();
    assert_eq!(y3.data, y4.data, "v3 and v4 loads disagree");
}

#[test]
fn random_byte_soup_never_panics() {
    prop::for_cases(23, 500, |case| {
        let n = prop::usize_in(23, case, 0, 4096);
        let soup: Vec<u8> =
            prop::i8s(case + 7, n).into_iter().map(|b| b as u8).collect();
        // Virtually all soups fail magic; the property is "returns".
        let _ = artifact::load_from_bytes(soup, LoadOptions::default());
    });
    // Soups that start with a valid magic + plausible header reach the
    // deeper validators.
    prop::for_cases(29, 200, |case| {
        let n = prop::usize_in(29, case, 64, 2048);
        let mut soup: Vec<u8> =
            prop::i8s(case + 13, n).into_iter().map(|b| b as u8).collect();
        soup[0..8].copy_from_slice(b"FATM0001");
        soup[8..16].copy_from_slice(&(soup.len() as u64).to_le_bytes());
        soup[28..32].copy_from_slice(&3u32.to_le_bytes());
        fix_digest(&mut soup);
        assert!(
            artifact::load_from_bytes(soup, LoadOptions::default()).is_err(),
            "case {case}: random section table accepted"
        );
    });
}
