//! Protocol battery for the socket front-end (`fat::net`, DESIGN.md
//! §10): truncated, oversized, split-across-reads and garbage-byte
//! requests against the pure parsers **and** a live loopback server.
//! The contract under attack input is narrow and absolute — the server
//! answers a well-formed error or closes the connection cleanly; it
//! never panics and never hangs (every read here carries a deadline, so
//! a hang fails the test). Happy-path responses must stay bit-exact
//! with `run_quant_ref` even when the request arrives a few bytes at a
//! time. (CI re-runs this file under `FAT_THREADS=1` and `8`.)

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::time::Duration;

use fat::int8::serve::{EngineOptions, InferClient, Int8Engine};
use fat::int8::{QModel, QTensor};
use fat::model::store::{Site, SitesJson};
use fat::model::{GraphDef, Op};
use fat::net::client::parse_logits_json;
use fat::net::{
    frame, http, FrameClient, HttpClient, Limits, ModelRegistry, Server,
    ServerOptions, Step,
};
use fat::quant::calibrate::CalibStats;
use fat::quant::export::{build_qmodel, QuantMode, Trained};
use fat::tensor::Tensor;
use fat::util::json::Json;
use fat::util::prop;

/// Tiny gap→dense model: big enough to produce nontrivial logits,
/// small enough that a debug-build battery stays fast.
const GRAPH: &str = r#"{
  "name": "proto", "num_classes": 3,
  "nodes": [
    {"id": "input", "op": "input", "inputs": [], "shape": [4, 4, 2]},
    {"id": "g", "op": "gap", "inputs": ["input"]},
    {"id": "d", "op": "dense", "inputs": ["g"], "cin": 2, "cout": 3, "bias": true}
  ]}"#;

const H: usize = 4;
const W: usize = 4;
const C: usize = 2;
const PER_IMG: usize = H * W * C;
const IMAGES: usize = 3;

fn model() -> QModel {
    let g = GraphDef::from_json(GRAPH).unwrap();
    let mut w = BTreeMap::new();
    let mut seed = 900u64;
    for n in g.conv_like() {
        let (wlen, cout) = match n.op {
            Op::Conv => (n.k * n.k * n.cin * n.cout, n.cout),
            Op::DwConv => (n.k * n.k * n.ch, n.ch),
            Op::Dense => (n.cin * n.cout, n.cout),
            _ => unreachable!(),
        };
        w.insert(
            format!("{}.w", n.id),
            Tensor::f32(vec![wlen], prop::f32s(seed, wlen, -0.6, 0.6)),
        );
        w.insert(
            format!("{}.b", n.id),
            Tensor::f32(vec![cout], prop::f32s(seed + 1, cout, -0.2, 0.2)),
        );
        seed += 2;
    }
    let s = SitesJson {
        sites: g
            .sites()
            .into_iter()
            .map(|(id, unsigned)| Site { id, unsigned })
            .collect(),
        channel_stats: vec![],
        weight_order: g.folded_weight_order(),
        val_acc_fp_pretrain: -1.0,
    };
    let mut st = CalibStats::new(s.sites.len());
    for (i, site) in s.sites.iter().enumerate() {
        let lo = if site.unsigned { 0.0 } else { -2.0 - 0.1 * i as f32 };
        st.site_minmax[i].update(lo, 2.5 + 0.2 * i as f32);
    }
    st.batches = 1;
    let tr = Trained::identity(&g, QuantMode::SymVector, s.sites.len());
    build_qmodel(&g, &w, &s, &st, QuantMode::SymVector, &tr).unwrap()
}

fn pixels(img: usize) -> Vec<u8> {
    (0..PER_IMG)
        .map(|i| ((i * 37 + img * 101 + 5) % 256) as u8)
        .collect()
}

fn oracle_rows(qm: &QModel) -> Vec<Vec<f32>> {
    (0..IMAGES)
        .map(|img| {
            let x: Vec<f32> =
                pixels(img).iter().map(|&p| p as f32 / 255.0).collect();
            let q = QTensor::quantize(vec![1, H, W, C], &x, qm.input_qp);
            qm.run_quant_ref(q).unwrap().dequantize()
        })
        .collect()
}

fn assert_row_eq(got: &[f32], want: &[f32], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}");
    for i in 0..got.len() {
        assert_eq!(
            got[i].to_bits(),
            want[i].to_bits(),
            "{tag} logit {i}: {} != {}",
            got[i],
            want[i]
        );
    }
}

/// Boot a single-model loopback server (the "proto" endpoint).
fn boot() -> (Server, SocketAddr) {
    let engine = Int8Engine::new(model(), EngineOptions::threads(2));
    let registry = ModelRegistry::new();
    registry.insert("proto", engine);
    let server =
        Server::bind("127.0.0.1:0", registry, ServerOptions::default())
            .unwrap();
    let addr = server.local_addr();
    (server, addr)
}

/// Raw attack socket with bounded reads — a server hang fails the test
/// as a read-timeout unwrap instead of wedging the suite.
fn raw(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.set_write_timeout(Some(Duration::from_secs(5))).unwrap();
    s
}

/// Every byte the server sent back must parse as a sequence of
/// well-formed messages of the protocol the connection spoke.
fn assert_well_formed(buf: &[u8], is_frame: bool) {
    let limits = Limits::default();
    let mut rest = buf;
    while !rest.is_empty() {
        if is_frame {
            match frame::parse_response(rest, &limits).unwrap() {
                Step::Done(_, used) => rest = &rest[used..],
                Step::Incomplete => panic!("truncated frame response"),
            }
        } else {
            match http::parse_response(rest, &limits).unwrap() {
                Step::Done(_, used) => rest = &rest[used..],
                Step::Incomplete => panic!("truncated http response"),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Pure parsers under fire (no sockets)
// ---------------------------------------------------------------------

#[test]
fn parsers_never_panic_on_byte_soup() {
    let limits = Limits::default();
    prop::for_cases(11, 300, |case| {
        let n = prop::usize_in(11, case, 0, 600);
        let bytes: Vec<u8> =
            prop::i8s(case, n).into_iter().map(|b| b as u8).collect();
        // Any Ok/Err outcome is fine; the property is "returns".
        let _ = http::parse_request(&bytes, &limits);
        let _ = http::parse_response(&bytes, &limits);
        let _ = frame::parse_request(&bytes, &limits);
        let _ = frame::parse_response(&bytes, &limits);
    });
}

#[test]
fn single_byte_mutations_of_a_valid_request_never_panic() {
    let limits = Limits::default();
    let wire = http::request(
        "POST",
        "/v1/models/proto/infer",
        "application/octet-stream",
        &pixels(0),
    );
    for i in 0..wire.len() {
        for delta in [1u8, 0x80] {
            let mut m = wire.clone();
            m[i] = m[i].wrapping_add(delta);
            let _ = http::parse_request(&m, &limits);
        }
    }
    let fwire = frame::encode_request(frame::OP_INFER, "proto", &pixels(0));
    for i in 0..fwire.len() {
        let mut m = fwire.clone();
        m[i] = m[i].wrapping_add(1);
        let _ = frame::parse_request(&m, &limits);
    }
}

// ---------------------------------------------------------------------
// Live server under fire
// ---------------------------------------------------------------------

#[test]
fn garbage_bytes_get_an_error_or_a_clean_close() {
    let (server, addr) = boot();
    prop::for_cases(7, 12, |case| {
        let n = prop::usize_in(7, case, 1, 256);
        let mut bytes: Vec<u8> = prop::i8s(case + 100, n)
            .into_iter()
            .map(|b| b as u8)
            .collect();
        // Alternate protocols: even cases attack the HTTP parser, odd
        // cases the frame parser.
        if case % 2 == 0 {
            if bytes[0] == frame::MAGIC[0] {
                bytes[0] = b'G';
            }
        } else {
            bytes[0] = frame::MAGIC[0];
        }
        let is_frame = bytes[0] == frame::MAGIC[0];
        let mut s = raw(addr);
        s.write_all(&bytes).unwrap();
        s.shutdown(Shutdown::Write).unwrap();
        let mut buf = Vec::new();
        // EOF (clean close) or a finite answer; a hang trips the
        // 5s deadline and fails the unwrap.
        s.read_to_end(&mut buf).unwrap();
        assert_well_formed(&buf, is_frame);
    });
    // The server survived the soup and still serves.
    let qm = model();
    let oracle = oracle_rows(&qm);
    let mut c = HttpClient::connect(addr, "proto").unwrap();
    let got = c.infer_one(&pixels(0)).unwrap();
    assert_row_eq(&got, &oracle[0], "after garbage");
    drop(c);
    server.drain(Duration::from_secs(2));
    assert_eq!(server.stats().open_conns, 0);
}

#[test]
fn split_across_reads_request_is_served_bit_exact() {
    let (server, addr) = boot();
    let qm = model();
    let oracle = oracle_rows(&qm);
    let px = pixels(1);
    let head = format!(
        "POST /v1/models/proto/infer HTTP/1.1\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n",
        px.len()
    );
    let wire = [head.as_bytes(), &px[..]].concat();
    let mut s = raw(addr);
    // Dribble the request a few bytes per write, with pauses, so the
    // server's incremental parser sees many Incomplete rounds.
    for chunk in wire.chunks(7) {
        s.write_all(chunk).unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let Step::Done(resp, used) =
        http::parse_response(&buf, &Limits::default()).unwrap()
    else {
        panic!("truncated response");
    };
    assert_eq!(used, buf.len());
    assert_eq!(resp.status, 200);
    let got =
        parse_logits_json(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    assert_row_eq(&got, &oracle[1], "split-across-reads");
    server.drain(Duration::from_secs(2));
}

#[test]
fn oversized_content_length_is_rejected_promptly() {
    let (server, addr) = boot();
    let mut s = raw(addr);
    let head = format!(
        "POST /v1/models/proto/infer HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
        64 << 20
    );
    let t0 = std::time::Instant::now();
    s.write_all(head.as_bytes()).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    // Answered from the header alone — no waiting for 64 MiB that will
    // never arrive.
    assert!(t0.elapsed() < Duration::from_secs(2));
    let text = String::from_utf8_lossy(&buf);
    assert!(text.starts_with("HTTP/1.1 413"), "{text}");
    assert!(server.stats().malformed >= 1);
    server.drain(Duration::from_secs(2));
}

#[test]
fn pipelined_requests_get_pipelined_responses() {
    let (server, addr) = boot();
    let qm = model();
    let oracle = oracle_rows(&qm);
    let mut wire = http::request(
        "POST",
        "/v1/models/proto/infer",
        "application/octet-stream",
        &pixels(0),
    );
    wire.extend_from_slice(&http::request(
        "POST",
        "/v1/models/proto/infer",
        "application/octet-stream",
        &pixels(2),
    ));
    let mut s = raw(addr);
    s.write_all(&wire).unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let limits = Limits::default();
    let Step::Done(r0, used) = http::parse_response(&buf, &limits).unwrap()
    else {
        panic!("truncated first response");
    };
    let Step::Done(r1, used1) =
        http::parse_response(&buf[used..], &limits).unwrap()
    else {
        panic!("truncated second response");
    };
    assert_eq!(used + used1, buf.len());
    assert_eq!((r0.status, r1.status), (200, 200));
    for (resp, img) in [(&r0, 0usize), (&r1, 2usize)] {
        let got = parse_logits_json(std::str::from_utf8(&resp.body).unwrap())
            .unwrap();
        assert_row_eq(&got, &oracle[img], &format!("pipelined img {img}"));
    }
    server.drain(Duration::from_secs(2));
}

#[test]
fn frame_protocol_over_a_live_socket() {
    let (server, addr) = boot();
    let qm = model();
    let oracle = oracle_rows(&qm);
    // Happy path: raw f32 logits, bit-exact by construction.
    let mut c = FrameClient::connect(addr, "proto").unwrap();
    for img in 0..IMAGES {
        let got = c.infer_one(&pixels(img)).unwrap();
        assert_row_eq(&got, &oracle[img], &format!("frame img {img}"));
    }
    // Stats travel over frames too, as the same JSON document.
    let j = Json::parse(&c.stats().unwrap()).unwrap();
    assert_eq!(j.usize_or("completed", 0), IMAGES);
    drop(c);
    // Bad magic: a well-formed error frame, then close.
    let mut s = raw(addr);
    s.write_all(&[frame::MAGIC[0], 0x00, 1, 2, 3]).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let Step::Done(resp, _) =
        frame::parse_response(&buf, &Limits::default()).unwrap()
    else {
        panic!("truncated error frame");
    };
    assert_eq!(resp.status, frame::ST_BAD_REQUEST);
    // Oversized body length: rejected from the header, connection cut.
    let mut s = raw(addr);
    let mut req = frame::encode_request(frame::OP_INFER, "proto", &[]);
    let at = req.len() - 4;
    req[at..].copy_from_slice(&u32::MAX.to_le_bytes());
    s.write_all(&req).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let Step::Done(resp, _) =
        frame::parse_response(&buf, &Limits::default()).unwrap()
    else {
        panic!("truncated oversize answer");
    };
    assert_eq!(resp.status, frame::ST_BAD_REQUEST);
    // Unknown opcode: error frame, connection stays usable.
    let mut s = raw(addr);
    s.write_all(&frame::encode_request(99, "proto", &[])).unwrap();
    s.write_all(&frame::encode_request(frame::OP_STATS, "", &[])).unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    assert_well_formed(&buf, true);
    server.drain(Duration::from_secs(2));
}

#[test]
fn routing_errors_are_precise() {
    let (server, addr) = boot();
    // Unknown model over HTTP: 404.
    let mut c = HttpClient::connect(addr, "nope").unwrap();
    let (status, _) = c.infer_status(&pixels(0)).unwrap();
    assert_eq!(status, 404);
    drop(c);
    // Unknown model over frames: ST_NOT_FOUND.
    let mut fc = FrameClient::connect(addr, "nope").unwrap();
    let (fstatus, _) = fc.infer_status(&pixels(0)).unwrap();
    assert_eq!(fstatus, frame::ST_NOT_FOUND);
    drop(fc);
    // Wrong method on the infer path: 405. Unknown path: 404.
    for (req, want) in [
        (
            "GET /v1/models/proto/infer HTTP/1.1\r\nConnection: close\r\n\r\n",
            "HTTP/1.1 405",
        ),
        ("GET /nope HTTP/1.1\r\nConnection: close\r\n\r\n", "HTTP/1.1 404"),
    ] {
        let mut s = raw(addr);
        s.write_all(req.as_bytes()).unwrap();
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with(want), "{req:?} -> {text}");
    }
    server.drain(Duration::from_secs(2));
}

#[test]
fn keep_alive_serves_sequential_requests_and_stats_reconcile() {
    let (server, addr) = boot();
    let qm = model();
    let oracle = oracle_rows(&qm);
    let mut c = HttpClient::connect(addr, "proto").unwrap();
    for r in 0..6 {
        let img = r % IMAGES;
        let got = c.infer_one(&pixels(img)).unwrap();
        assert_row_eq(&got, &oracle[img], &format!("keep-alive req {r}"));
    }
    let j = Json::parse(&c.stats().unwrap()).unwrap();
    assert_eq!(j.usize_or("completed", 0), 6);
    assert_eq!(j.usize_or("rejected", 99), 0);
    assert_eq!(j.usize_or("failed", 99), 0);
    assert_eq!(j.usize_or("open_conns", 0), 1, "one keep-alive connection");
    let m = j
        .get("models")
        .and_then(|ms| ms.get("proto"))
        .expect("per-model stats present");
    assert_eq!(m.usize_or("requests", 0), 6);
    // A wrong-sized body is a client error (400), not a connection
    // killer: the same connection keeps serving afterwards.
    let (status, _) = c.infer_status(&[1, 2, 3]).unwrap();
    assert_eq!(status, 400);
    let got = c.infer_one(&pixels(0)).unwrap();
    assert_row_eq(&got, &oracle[0], "after 400");
    drop(c);
    server.drain(Duration::from_secs(2));
    assert_eq!(server.stats().open_conns, 0);
}
