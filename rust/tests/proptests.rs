//! Seeded property tests over the pure substrates (in-tree `util::prop`
//! replaces proptest on this offline box). Each case is deterministic and
//! reproducible from its printed index.

use fat::int8::kernels::{self, Blocking, Isa, PackedWeights};
use fat::int8::qtensor::{to_i8_domain, QTensor};
use fat::int8::{gemm, im2col, ops, tune};
use fat::quant::scale::{
    apply_multiplier, quantize_multiplier, rounding_rshift, QParams,
};
use fat::quant::thresholds as th;
use fat::util::prop;

#[test]
fn prop_fake_quant_error_bounded() {
    // |x - fq(x)| <= step/2 inside the representable range, for any T.
    prop::for_cases(11, 200, |case| {
        let t = 0.05 + prop::f32s(case, 1, 0.0, 8.0)[0];
        let qp = QParams::symmetric_signed(t);
        for &x in &prop::f32s(case + 1000, 64, -t, t) {
            let err = (x - qp.fake_quant(x)).abs();
            assert!(
                err <= qp.scale / 2.0 + 1e-6,
                "case {case}: x={x} t={t} err={err}"
            );
        }
    });
}

#[test]
fn prop_fake_quant_idempotent_and_monotone() {
    prop::for_cases(13, 100, |case| {
        let t = 0.1 + prop::f32s(case, 1, 0.0, 4.0)[0];
        let qp = QParams::symmetric_signed(t);
        let mut xs = prop::f32s(case + 500, 32, -2.0 * t, 2.0 * t);
        xs.sort_by(|a, b| a.total_cmp(b));
        let mut prev = f32::NEG_INFINITY;
        for &x in &xs {
            let y = qp.fake_quant(x);
            assert!((qp.fake_quant(y) - y).abs() <= 1e-6, "idempotent");
            assert!(y >= prev - 1e-6, "monotone: {y} < {prev}");
            prev = y;
        }
    });
}

#[test]
fn prop_asym_zero_exactly_representable() {
    // After zero-point nudging, real 0.0 must round-trip exactly
    // whenever 0 lies within the range (Jacob et al. requirement).
    prop::for_cases(17, 200, |case| {
        let left = prop::f32s(case, 1, -4.0, -0.01)[0];
        let width = 0.1 + prop::f32s(case + 1, 1, 0.0, 8.0)[0];
        let qp = QParams::asymmetric(left, width);
        if left <= 0.0 && left + width >= 0.0 {
            assert_eq!(
                qp.fake_quant(0.0),
                0.0,
                "case {case}: left={left} width={width} zp={}",
                qp.zero_point
            );
        }
    });
}

#[test]
fn prop_multiplier_roundtrip_accuracy() {
    prop::for_cases(19, 300, |case| {
        let m = (prop::f32s(case, 1, -14.0, 1.5)[0] as f64).exp2();
        let (m0, shift) = quantize_multiplier(m);
        let recon = m0 as f64 / (1u64 << 31) as f64 / 2f64.powi(shift);
        assert!(
            ((recon - m) / m).abs() < 1e-6,
            "case {case}: m={m} recon={recon}"
        );
    });
}

#[test]
fn prop_fixed_point_requant_close_to_float() {
    prop::for_cases(23, 100, |case| {
        let m = (prop::f32s(case, 1, -12.0, -2.0)[0] as f64).exp2();
        let (m0, shift) = quantize_multiplier(m);
        for i in 0..50 {
            let acc = (prop::usize_in(case, i, 0, 4_000_000) as i64
                - 2_000_000) as i32;
            let fx = apply_multiplier(acc, m0, shift);
            let fl = (acc as f64 * m).round() as i32;
            assert!(
                (fx - fl).abs() <= 1,
                "case {case}: acc={acc} m={m} fx={fx} fl={fl}"
            );
        }
    });
}

#[test]
fn prop_gemm_matches_reference() {
    prop::for_cases(29, 40, |case| {
        let m = prop::usize_in(case, 0, 1, 17);
        let k = prop::usize_in(case, 1, 1, 40);
        let n = prop::usize_in(case, 2, 1, 23);
        let zp = prop::usize_in(case, 3, 0, 33) as i32 - 16;
        let a = prop::i8s(case + 100, m * k);
        let b = prop::i8s(case + 200, k * n);
        let sums = gemm::col_sums(&b, k, n);
        let mut out = vec![0i32; m * n];
        gemm::gemm_i8(&a, zp, &b, &sums, m, k, n, &mut out);
        assert_eq!(
            out,
            gemm::gemm_ref(&a, zp, &b, m, k, n),
            "case {case}: ({m},{k},{n}) zp={zp}"
        );
    });
}

#[test]
fn prop_gemm_parallel_matches_reference_across_threads() {
    prop::for_cases(59, 30, |case| {
        let m = prop::usize_in(case, 0, 1, 33);
        let k = prop::usize_in(case, 1, 1, 70);
        let n = prop::usize_in(case, 2, 1, 40);
        let zp = prop::usize_in(case, 3, 0, 33) as i32 - 16;
        let a = prop::i8s(case + 300, m * k);
        let b = prop::i8s(case + 400, k * n);
        let sums = gemm::col_sums(&b, k, n);
        let want = gemm::gemm_ref(&a, zp, &b, m, k, n);
        for threads in [1usize, 2, 8] {
            let mut out = vec![0i32; m * n];
            gemm::gemm_i8_parallel(
                &a, zp, &b, &sums, m, k, n, &mut out, threads,
            );
            assert_eq!(out, want, "case {case}: ({m},{k},{n}) t={threads}");
        }
    });
}

#[test]
fn prop_packed_simd_gemm_matches_reference_on_blocking_edges() {
    // The curated blocking-edge shapes × every runtime-detected ISA ×
    // thread counts {1, 2, 8}: the packed SIMD kernels and the
    // pool-sharded dispatch must be bit-exact with the naive oracle.
    for &(m, k, n, zp) in prop::SHAPES {
        let a = prop::i8s(61, m * k);
        let b = prop::i8s(62, k * n);
        let sums = gemm::col_sums(&b, k, n);
        let pw = PackedWeights::pack(&b, k, n);
        let want = gemm::gemm_ref(&a, zp, &b, m, k, n);
        for isa in Isa::available() {
            for threads in [1usize, 2, 8] {
                let mut out = vec![0i32; m * n];
                kernels::gemm_packed_parallel(
                    &a,
                    zp,
                    &pw,
                    &sums,
                    m,
                    &mut out,
                    threads,
                    isa,
                    Blocking::default(),
                );
                assert_eq!(
                    out,
                    want,
                    "({m},{k},{n}) zp={zp} t={threads} isa={}",
                    isa.name()
                );
            }
        }
    }
}

#[test]
fn prop_packed_simd_gemm_matches_reference_random_shapes() {
    prop::for_cases(67, 25, |case| {
        let m = prop::usize_in(case, 0, 1, 33);
        let k = prop::usize_in(case, 1, 1, 70);
        let n = prop::usize_in(case, 2, 1, 80);
        let zp = prop::usize_in(case, 3, 0, 61) as i32 - 30;
        let a = prop::i8s(case + 500, m * k);
        let b = prop::i8s(case + 600, k * n);
        let sums = gemm::col_sums(&b, k, n);
        let pw = PackedWeights::pack(&b, k, n);
        let want = gemm::gemm_ref(&a, zp, &b, m, k, n);
        for isa in Isa::available() {
            for threads in [1usize, 2, 8] {
                let mut out = vec![0i32; m * n];
                kernels::gemm_packed_parallel(
                    &a,
                    zp,
                    &pw,
                    &sums,
                    m,
                    &mut out,
                    threads,
                    isa,
                    Blocking::default(),
                );
                assert_eq!(
                    out,
                    want,
                    "case {case}: ({m},{k},{n}) t={threads} isa={}",
                    isa.name()
                );
            }
        }
    });
}

#[test]
fn prop_int4_packed_gemm_matches_reference() {
    // Nibble-packed weight panels must be bit-exact with the naive
    // int8 oracle over the *same* (int4-valued) weights — across random
    // shapes × every runtime-detected ISA × thread counts {1, 2, 8} ×
    // both tuner-reachable strip widths.
    prop::for_cases(79, 25, |case| {
        let m = prop::usize_in(case, 0, 1, 33);
        let k = prop::usize_in(case, 1, 1, 70);
        let n = prop::usize_in(case, 2, 1, 80);
        let zp = prop::usize_in(case, 3, 0, 61) as i32 - 30;
        let a = prop::i8s(case + 900, m * k);
        // the export grid is [-7, 7]: fold random i8s into int4 range
        let b: Vec<i8> =
            prop::i8s(case + 950, k * n).iter().map(|v| v % 8).collect();
        assert!(kernels::fits_int4(&b));
        let sums = gemm::col_sums(&b, k, n);
        let want = gemm::gemm_ref(&a, zp, &b, m, k, n);
        for nrw in [16usize, 32] {
            let pw = PackedWeights::pack_bits(&b, k, n, nrw, 4);
            let bk = Blocking { nr: nrw, ..Blocking::default() };
            for isa in Isa::available() {
                for threads in [1usize, 2, 8] {
                    let mut out = vec![0i32; m * n];
                    kernels::gemm_packed_parallel(
                        &a, zp, &pw, &sums, m, &mut out, threads, isa, bk,
                    );
                    assert_eq!(
                        out,
                        want,
                        "case {case}: ({m},{k},{n}) zp={zp} nr={nrw} \
                         t={threads} isa={}",
                        isa.name()
                    );
                }
            }
        }
    });
}

#[test]
fn prop_pow2_int4_pipeline_matches_scalar_oracle() {
    // The deployed pow2 × int4 combination end to end at the kernel
    // level: nibble-packed GEMM feeding the shift-only epilogue must be
    // bit-exact with `gemm_ref` + scalar `rounding_rshift`, across ISA
    // × threads {1, 2, 8}. This is the ISSUE-9 acceptance property —
    // the multiplier epilogue double-rounds, so the oracle here is the
    // shift form itself, not `apply_multiplier`.
    prop::for_cases(83, 15, |case| {
        let m = prop::usize_in(case, 0, 1, 17);
        let k = prop::usize_in(case, 1, 1, 50);
        let cout = prop::usize_in(case, 2, 1, 40);
        let zp = prop::usize_in(case, 3, 0, 33) as i32 - 16;
        let a = prop::i8s(case + 100, m * k);
        let b: Vec<i8> =
            prop::i8s(case + 200, k * cout).iter().map(|v| v % 8).collect();
        let sums = gemm::col_sums(&b, k, cout);
        let bias: Vec<i32> = prop::f32s(case + 300, cout, -400.0, 400.0)
            .iter()
            .map(|&v| v as i32)
            .collect();
        let shift: Vec<i32> = (0..cout)
            .map(|c| prop::usize_in(case, 4 + c as u64, 0, 11) as i32)
            .collect();
        let out_qp = to_i8_domain(QParams::asymmetric(-1.0, 2.0));
        let clamp = (-128i32, 127i32);
        // scalar oracle over the unpacked reference GEMM
        let acc_ref = gemm::gemm_ref(&a, zp, &b, m, k, cout);
        let want: Vec<i8> = acc_ref
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                let c = i % cout;
                (rounding_rshift(v + bias[c], shift[c]) + out_qp.zero_point)
                    .clamp(clamp.0, clamp.1) as i8
            })
            .collect();
        let pw = PackedWeights::pack_bits(&b, k, cout, 16, 4);
        let bk = Blocking { nr: 16, ..Blocking::default() };
        for isa in Isa::available() {
            for threads in [1usize, 2, 8] {
                let mut acc = vec![0i32; m * cout];
                kernels::gemm_packed_parallel(
                    &a, zp, &pw, &sums, m, &mut acc, threads, isa, bk,
                );
                let mut got = Vec::new();
                ops::requant_store_shift(
                    &acc, &bias, &shift, out_qp, clamp, cout, &mut got, isa,
                );
                assert_eq!(
                    got,
                    want,
                    "case {case}: ({m},{k},{cout}) zp={zp} t={threads} \
                     isa={}",
                    isa.name()
                );
            }
        }
    });
}

#[test]
fn prop_tuner_candidate_blockings_match_reference() {
    // Every schedule the autotuner may pick — the full candidate grid
    // plus hand-picked extremes — must be bit-exact with the naive
    // oracle across random shapes × every runtime-detected ISA ×
    // thread counts {1, 2, 8}. This is the property that makes tuning
    // safe to run without accuracy re-validation.
    let mut topts = tune::TuneOptions::full();
    topts.threads = 2;
    let mut blockings = tune::candidates(&topts);
    for bk in [
        Blocking { kc: 2, nr: 16, mr: 1, grain: 1 },
        Blocking { kc: 8192, nr: 16, mr: 5, grain: 4096 },
        Blocking { kc: 6, nr: 48, mr: 7, grain: 3 },
    ] {
        bk.validate().unwrap();
        blockings.push(bk);
    }
    prop::for_cases(71, 8, |case| {
        let m = prop::usize_in(case, 0, 1, 21);
        let k = prop::usize_in(case, 1, 1, 70);
        let n = prop::usize_in(case, 2, 1, 80);
        let zp = prop::usize_in(case, 3, 0, 61) as i32 - 30;
        let a = prop::i8s(case + 700, m * k);
        let b = prop::i8s(case + 800, k * n);
        let sums = gemm::col_sums(&b, k, n);
        let want = gemm::gemm_ref(&a, zp, &b, m, k, n);
        for bk in &blockings {
            let pw = PackedWeights::pack_with(&b, k, n, bk.nr);
            for isa in Isa::available() {
                for threads in [1usize, 2, 8] {
                    let mut out = vec![0i32; m * n];
                    kernels::gemm_packed_parallel(
                        &a, zp, &pw, &sums, m, &mut out, threads, isa, *bk,
                    );
                    assert_eq!(
                        out,
                        want,
                        "case {case}: ({m},{k},{n}) zp={zp} bk={} \
                         t={threads} isa={}",
                        bk.label(),
                        isa.name()
                    );
                }
            }
        }
    });
}

#[test]
fn prop_pool_sharded_gemm_matches_reference_on_blocking_edges() {
    // The unpacked kernel's pool-sharded path over the same edge shapes
    // (it serves ad-hoc layers; 64 shards exceed the worker cap, so this
    // also exercises shard multiplexing).
    for &(m, k, n, zp) in prop::SHAPES {
        let a = prop::i8s(71, m * k);
        let b = prop::i8s(72, k * n);
        let sums = gemm::col_sums(&b, k, n);
        let want = gemm::gemm_ref(&a, zp, &b, m, k, n);
        for threads in [1usize, 2, 8, 64] {
            let mut out = vec![0i32; m * n];
            gemm::gemm_i8_parallel(
                &a, zp, &b, &sums, m, k, n, &mut out, threads,
            );
            assert_eq!(out, want, "({m},{k},{n}) t={threads}");
        }
    }
}

#[test]
fn prop_dw_tap_kernel_matches_scalar() {
    prop::for_cases(73, 40, |case| {
        let c = prop::usize_in(case, 0, 1, 70);
        let zp = prop::usize_in(case, 1, 0, 255) as i32 - 128;
        let x = prop::i8s(case + 700, c);
        let w = prop::i8s(case + 800, c);
        let mut want = vec![-5i32; c];
        // scalar oracle via the public entry point
        kernels::dw_accum_tap(&mut want, &x, &w, zp, Isa::Scalar);
        for isa in Isa::available() {
            let mut acc = vec![-5i32; c];
            kernels::dw_accum_tap(&mut acc, &x, &w, zp, isa);
            assert_eq!(acc, want, "case {case}: c={c} zp={zp} {}", isa.name());
        }
    });
}

#[test]
fn prop_im2col_patches_contain_input_values_or_zp() {
    prop::for_cases(31, 30, |case| {
        let h = prop::usize_in(case, 0, 3, 12);
        let w = prop::usize_in(case, 1, 3, 12);
        let c = prop::usize_in(case, 2, 1, 5);
        let k = [1usize, 3, 5][prop::usize_in(case, 3, 0, 3)];
        let stride = 1 + prop::usize_in(case, 4, 0, 2);
        let zp = -7i8;
        let x = prop::i8s(case + 50, h * w * c);
        let (p, oh, ow) = im2col::im2col_i8(&x, 1, h, w, c, k, stride, zp);
        assert_eq!(p.len(), oh * ow * k * k * c);
        assert_eq!(oh, h.div_ceil(stride));
        use std::collections::HashSet;
        let valid: HashSet<i8> = x.iter().copied().chain([zp]).collect();
        assert!(p.iter().all(|v| valid.contains(v)), "case {case}");
    });
}

#[test]
fn prop_fused_conv_matches_reference() {
    // ISSUE-10 acceptance property: the fused implicit-GEMM conv (no
    // materialized patch matrix, no i32 accumulator round-trip) must be
    // bit-exact with BOTH the staged im2col path and a scalar
    // `gemm_ref` + epilogue oracle — across random SAME-padded shapes ×
    // stride {1, 2} × epilogue {multiplier, shift} × weight width
    // {int8, int4} × every runtime-detected ISA × threads {1, 2, 8}.
    use fat::int8::QLayer;
    prop::for_cases(97, 6, |case| {
        let n = 1 + prop::usize_in(case, 0, 0, 2);
        let h = prop::usize_in(case, 1, 3, 9);
        let w = prop::usize_in(case, 2, 3, 9);
        let c = prop::usize_in(case, 3, 1, 5);
        let cout = prop::usize_in(case, 4, 1, 20);
        let k = [1usize, 3, 5][prop::usize_in(case, 5, 0, 3)];
        let x_qp = to_i8_domain(QParams::asymmetric(-1.0, 2.0));
        let x = QTensor {
            shape: vec![n, h, w, c],
            data: prop::i8s(case + 100, n * h * w * c),
            qp: x_qp,
        };
        let kk = k * k * c;
        let out_qp = to_i8_domain(QParams::asymmetric(-2.0, 4.0));
        let clamp = (-128i32, 127i32);
        let bias: Vec<i32> = prop::f32s(case + 300, cout, -300.0, 300.0)
            .iter()
            .map(|&v| v as i32)
            .collect();
        let requant: Vec<(i32, i32)> = (0..cout)
            .map(|ci| {
                quantize_multiplier(
                    (2.0f64)
                        .powi(-(prop::usize_in(case, 40 + ci as u64, 4, 12)
                            as i32)),
                )
            })
            .collect();
        let shift: Vec<i32> = (0..cout)
            .map(|ci| prop::usize_in(case, 80 + ci as u64, 4, 12) as i32)
            .collect();
        for bits in [8usize, 4] {
            let w_q: Vec<i8> = if bits == 4 {
                prop::i8s(case + 200, kk * cout)
                    .iter()
                    .map(|v| v % 8)
                    .collect()
            } else {
                prop::i8s(case + 200, kk * cout)
            };
            let sums = gemm::col_sums(&w_q, kk, cout);
            let (nr, pw) = if bits == 4 {
                (16, PackedWeights::pack_bits(&w_q, kk, cout, 16, 4))
            } else {
                let bk = Blocking::default();
                (bk.nr, PackedWeights::pack(&w_q, kk, cout))
            };
            let bk = Blocking { nr, ..Blocking::default() };
            for stride in [1usize, 2] {
                // scalar oracle: explicit im2col + naive GEMM
                let (patches, oh, ow) = im2col::im2col_i8(
                    &x.data,
                    n,
                    h,
                    w,
                    c,
                    k,
                    stride,
                    x_qp.zero_point as i8,
                );
                let m = n * oh * ow;
                let acc_ref = gemm::gemm_ref(
                    &patches,
                    x_qp.zero_point,
                    &w_q,
                    m,
                    kk,
                    cout,
                );
                for use_shift in [false, true] {
                    let want: Vec<i8> = acc_ref
                        .iter()
                        .enumerate()
                        .map(|(i, &v)| {
                            let ci = i % cout;
                            let y = if use_shift {
                                rounding_rshift(v + bias[ci], shift[ci])
                            } else {
                                let (m0, s) = requant[ci];
                                apply_multiplier(v + bias[ci], m0, s)
                            };
                            (y + out_qp.zero_point).clamp(clamp.0, clamp.1)
                                as i8
                        })
                        .collect();
                    let mk_layer = |fused: bool| QLayer {
                        w_q: w_q.clone().into(),
                        w_sums: sums.clone(),
                        bias_q: bias.clone(),
                        requant: requant.clone(),
                        requant_shift: use_shift.then(|| shift.clone()),
                        out_qp,
                        clamp,
                        w_scales: vec![1.0],
                        packed: Some(pw.clone()),
                        blocking: bk,
                        fused,
                    };
                    let staged_l = mk_layer(false);
                    let fused_l = mk_layer(true);
                    for isa in Isa::available() {
                        for threads in [1usize, 2, 8] {
                            let mut ctx = ops::OpCtx {
                                threads,
                                isa,
                                ..Default::default()
                            };
                            let staged = ops::conv2d(
                                &x, &staged_l, k, stride, cout, &mut ctx,
                                Vec::new(),
                            );
                            let fused = ops::conv2d_fused(
                                &x, &fused_l, k, stride, cout, &mut ctx,
                                Vec::new(), None,
                            );
                            let tag = format!(
                                "case {case}: ({n},{h},{w},{c})→{cout} \
                                 k={k} s={stride} bits={bits} \
                                 shift={use_shift} t={threads} isa={}",
                                isa.name()
                            );
                            assert_eq!(staged.shape, vec![n, oh, ow, cout]);
                            assert_eq!(staged.data, want, "staged {tag}");
                            assert_eq!(fused.shape, staged.shape, "{tag}");
                            assert_eq!(fused.data, want, "fused {tag}");
                        }
                    }
                }
            }
        }
    });
}

#[test]
fn prop_quantize_dequantize_within_one_step_under_i8_domain() {
    prop::for_cases(37, 100, |case| {
        let t = 0.2 + prop::f32s(case, 1, 0.0, 5.0)[0];
        let qp = to_i8_domain(QParams::symmetric_unsigned(t));
        let xs = prop::f32s(case + 10, 64, 0.0, t);
        let q = QTensor::quantize(vec![64], &xs, qp);
        for (a, b) in xs.iter().zip(q.dequantize()) {
            assert!((a - b).abs() <= qp.scale, "case {case}: {a} vs {b}");
        }
    });
}

#[test]
fn prop_per_channel_thresholds_dominate_values() {
    prop::for_cases(41, 60, |case| {
        let c = prop::usize_in(case, 0, 1, 9);
        let rows = prop::usize_in(case, 1, 1, 30);
        let w = prop::f32s(case + 5, rows * c, -3.0, 3.0);
        let t = th::per_channel_w_thresholds(&w, c);
        for (i, &v) in w.iter().enumerate() {
            assert!(v.abs() <= t[i % c] + 1e-6);
        }
        let tt = th::per_tensor_w_threshold(&w);
        assert!(t.iter().all(|&x| x <= tt + 1e-6));
    });
}

#[test]
fn prop_cosine_schedule_bounded_and_periodic() {
    use fat::coordinator::schedule::CosineRestarts;
    prop::for_cases(43, 50, |case| {
        let cycle = prop::usize_in(case, 0, 1, 50);
        let s = CosineRestarts::new(0.1, cycle);
        for t in 0..200 {
            let (lr, restart) = s.at(t);
            assert!(lr >= s.lr_min - 1e-9 && lr <= s.lr_max + 1e-9);
            assert_eq!(restart, t % cycle.max(1) == 0);
        }
    });
}

#[test]
fn prop_json_roundtrip_numbers_strings() {
    use fat::util::Json;
    prop::for_cases(47, 80, |case| {
        let v = prop::f32s(case, 1, -1e6, 1e6)[0] as f64;
        let j = Json::parse(&format!("{v}")).unwrap();
        assert!((j.as_f64().unwrap() - v).abs() <= v.abs() * 1e-12);
        let s = format!("k{}", prop::usize_in(case, 1, 0, 1000));
        let j = Json::parse(&format!("{{\"a\": \"{s}\"}}")).unwrap();
        assert_eq!(j.get("a").unwrap().as_str().unwrap(), s);
    });
}

#[test]
fn prop_dws_pattern_scales_respect_relu6_cap() {
    prop::for_cases(53, 60, |case| {
        let c = prop::usize_in(case, 0, 2, 12);
        let w = prop::f32s(case + 3, 9 * c, -2.0, 2.0);
        let ch_max: Vec<f32> = prop::f32s(case + 7, c, 0.1, 7.0);
        let (s, locked) =
            fat::quant::dws::pattern_scales(&w, &ch_max, c, true);
        for k in 0..c {
            if locked[k] {
                assert_eq!(s[k], 1.0);
                assert!(ch_max[k] >= fat::quant::dws::LOCK_LIMIT);
            } else {
                assert!(
                    ch_max[k] * s[k] <= fat::quant::dws::RELU6_CAP + 1e-3
                        || s[k] == fat::quant::dws::SCALE_MIN,
                    "case {case}: ch_max={} s={}",
                    ch_max[k],
                    s[k]
                );
            }
        }
    });
}
