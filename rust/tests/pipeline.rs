//! End-to-end pipeline tests over the real AOT artifacts: short FAT
//! runs checking stage composition, §3.3 invariants, int8 agreement and
//! artifact-vs-native backend agreement. Skipped gracefully when
//! `make artifacts` has not run or the build has no `pjrt` feature —
//! the artifact-free equivalents live in `rust/tests/fp_native.rs`.

use std::sync::Arc;

use fat::coordinator::PipelineConfig;
use fat::int8::serve::EngineOptions;
use fat::quant::backend::{ModelView, NativeExec, Executor};
use fat::quant::export::{QuantKnobs, QuantMode};
use fat::quant::session::{CalibOpts, QuantSession, QuantSpec, SessionCore};
use fat::runtime::{pjrt_available, Registry, Runtime};

fn setup() -> Option<(Arc<Registry>, std::path::PathBuf)> {
    let artifacts = fat::artifacts_dir();
    if !artifacts.join("models/mobilenet_v2_mini").exists() {
        eprintln!("SKIP: artifacts not built (native-backend coverage runs in fp_native.rs)");
        return None;
    }
    if !pjrt_available() {
        eprintln!("SKIP: no `pjrt` feature (native-backend coverage runs in fp_native.rs)");
        return None;
    }
    let rt = Runtime::cpu().ok()?;
    Some((Arc::new(Registry::new(Arc::new(rt))), artifacts))
}

macro_rules! need {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => return,
        }
    };
}

#[test]
fn fat_pipeline_composes_and_finetunes() {
    let (reg, artifacts) = need!(setup());
    let core = SessionCore::open(reg, &artifacts, "mobilenet_v2_mini").unwrap();
    let mode = QuantMode::SymVector;
    let stats = core.calibrate(50).unwrap();
    assert_eq!(stats.site_minmax.len(), core.sites.sites.len());
    for mm in &stats.site_minmax {
        assert!(mm.min <= mm.max);
    }

    let mut cfg = PipelineConfig::default();
    cfg.max_steps = 3;
    cfg.epochs = 1;
    cfg.val_images = 100;

    let (tr, losses) = core
        .finetune(mode, &stats, &cfg.finetune_opts(false), |_, _, _| {})
        .unwrap();
    assert_eq!(losses.len(), 3);
    assert!(losses.iter().all(|l| l.is_finite() && *l >= 0.0));
    // trainables moved
    let tr0 = core.identity_trainables(mode).unwrap();
    let moved = tr
        .iter()
        .any(|(k, t)| t.as_f32().unwrap() != tr0[k].as_f32().unwrap());
    assert!(moved, "finetune did not update any trainable");

    let acc = core.quant_accuracy(mode, &stats, &tr, 100).unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn dws_rescale_preserves_fp_accuracy() {
    let (reg, artifacts) = need!(setup());
    let mut core =
        SessionCore::open(reg, &artifacts, "mobilenet_v2_mini").unwrap();
    let before = core.fp_accuracy(200).unwrap();
    let stats = core.calibrate(50).unwrap();
    let reports = core.dws_rescale(&stats).unwrap();
    assert!(!reports.is_empty());
    let after = core.fp_accuracy(200).unwrap();
    assert!(
        (before - after).abs() <= 0.01,
        "rescale changed FP accuracy: {before} -> {after}"
    );
}

#[test]
fn inject_spread_preserves_fp_and_hurts_scalar_quant() {
    let (reg, artifacts) = need!(setup());
    let mut core =
        SessionCore::open(reg.clone(), &artifacts, "mobilenet_v2_mini")
            .unwrap();
    let fp_before = core.fp_accuracy(200).unwrap();
    let n = core
        .inject_spread(
            fat::coordinator::experiments::SPREAD_SEED,
            fat::coordinator::experiments::MOBILENET_SPREAD_LOG2,
        )
        .unwrap();
    assert!(n >= 5, "expected several DWS patterns, got {n}");
    let fp_after = core.fp_accuracy(200).unwrap();
    assert!(
        (fp_before - fp_after).abs() <= 0.01,
        "spread injection must be function-preserving: {fp_before} -> {fp_after}"
    );
    // scalar quantization now collapses relative to the clean model
    let stats = core.calibrate(50).unwrap();
    let tr0 = core.identity_trainables(QuantMode::SymScalar).unwrap();
    let q_spread = core
        .quant_accuracy(QuantMode::SymScalar, &stats, &tr0, 200)
        .unwrap();
    let core_clean =
        SessionCore::open(reg, &artifacts, "mobilenet_v2_mini").unwrap();
    let stats_c = core_clean.calibrate(50).unwrap();
    let q_clean = core_clean
        .quant_accuracy(QuantMode::SymScalar, &stats_c, &tr0, 200)
        .unwrap();
    assert!(
        q_spread < q_clean - 0.05,
        "spread should hurt scalar quant: {q_spread} vs clean {q_clean}"
    );
}

#[test]
fn int8_engine_agrees_with_fake_quant() {
    let (reg, artifacts) = need!(setup());
    let th = QuantSession::open(reg, &artifacts, "mnas_mini_10")
        .unwrap()
        .calibrate(CalibOpts::images(50))
        .unwrap()
        .identity(&QuantSpec::from_mode(QuantMode::SymVector))
        .unwrap();
    let fake = th.quant_accuracy(200).unwrap();
    let engine = th.serve(EngineOptions::default()).unwrap();
    let acc =
        fat::coordinator::experiments::int8_accuracy(&engine, 200).unwrap();
    assert!(
        (fake - acc).abs() <= 0.08,
        "engine {acc} vs fake-quant {fake}"
    );
    assert!(engine.param_bytes() > 10_000);
}

#[test]
fn asym_pipeline_runs() {
    let (reg, artifacts) = need!(setup());
    let core = SessionCore::open(reg, &artifacts, "mnas_mini_10").unwrap();
    let mode = QuantMode::AsymScalar;
    let stats = core.calibrate(50).unwrap();
    let mut cfg = PipelineConfig::default();
    cfg.max_steps = 2;
    cfg.epochs = 1;
    let (tr, losses) = core
        .finetune(mode, &stats, &cfg.finetune_opts(false), |_, _, _| {})
        .unwrap();
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(tr.contains_key("act_at") && tr.contains_key("act_ar"));
    let acc = core.quant_accuracy(mode, &stats, &tr, 100).unwrap();
    assert!(acc > 0.15, "asym quant collapsed unexpectedly: {acc}");
}

/// Backend agreement: on the same pretrained model + calibration, the
/// native fake-quant forward must closely track the AOT (PJRT-lowered)
/// fake-quant forward — both implement eq. 4–9 over identical site
/// parameters, so their accuracies may differ only by borderline-pixel
/// rounding.
#[test]
fn native_fake_quant_agrees_with_artifact_fake_quant() {
    let (reg, artifacts) = need!(setup());
    let core = SessionCore::open(reg, &artifacts, "mnas_mini_10").unwrap();
    let stats = core.calibrate(50).unwrap();
    let native = NativeExec;
    let view = ModelView {
        graph: &core.graph,
        sites: &core.sites,
        weights: &core.weights,
    };
    // the native FP32 forward must track the PJRT fp_forward
    let art_fp = core.fp_accuracy(200).unwrap();
    let nat_fp = native.fp_accuracy(&view, 200).unwrap();
    assert!(
        (art_fp - nat_fp).abs() <= 0.03,
        "fp: artifact {art_fp} vs native {nat_fp}"
    );
    for mode in [QuantMode::SymScalar, QuantMode::AsymVector] {
        let tr = native.identity_trainables(&view, mode).unwrap();
        let art_acc = core.quant_accuracy(mode, &stats, &tr, 200).unwrap();
        let nat_acc = native
            .quant_accuracy(&view, mode, QuantKnobs::default(), &stats, &tr, 200)
            .unwrap();
        assert!(
            (art_acc - nat_acc).abs() <= 0.05,
            "{mode:?}: artifact {art_acc} vs native {nat_acc}"
        );
    }
}
