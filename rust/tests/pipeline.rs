//! End-to-end pipeline tests: short FAT runs over the real artifacts,
//! checking stage composition, §3.3 invariants and int8 agreement.
//! Skipped gracefully before `make artifacts`. These intentionally keep
//! exercising the deprecated `Pipeline` shim (plus a shim-vs-session
//! equivalence check); the staged-API tests live in
//! `rust/tests/session_equiv.rs`.
#![allow(deprecated)]

use std::sync::Arc;

use fat::coordinator::{Pipeline, PipelineConfig};
use fat::int8::serve::{EngineOptions, Int8Engine};
use fat::quant::export::QuantMode;
use fat::quant::session::{CalibOpts, QuantSession, QuantSpec};
use fat::runtime::{Registry, Runtime};

fn setup() -> Option<(Arc<Registry>, std::path::PathBuf)> {
    let artifacts = fat::artifacts_dir();
    if !artifacts.join("models/mobilenet_v2_mini").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    let rt = Runtime::cpu().ok()?;
    Some((Arc::new(Registry::new(Arc::new(rt))), artifacts))
}

macro_rules! need {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => return,
        }
    };
}

#[test]
fn fat_pipeline_composes_and_finetunes() {
    let (reg, artifacts) = need!(setup());
    let p = Pipeline::new(reg, &artifacts, "mobilenet_v2_mini").unwrap();
    let mode = QuantMode::SymVector;
    let stats = p.calibrate(50).unwrap();
    assert_eq!(stats.site_minmax.len(), p.sites.sites.len());
    for mm in &stats.site_minmax {
        assert!(mm.min <= mm.max);
    }

    let mut cfg = PipelineConfig::default();
    cfg.max_steps = 3;
    cfg.epochs = 1;
    cfg.val_images = 100;

    let (tr, losses) = p.finetune(mode, &stats, &cfg, |_, _, _| {}).unwrap();
    assert_eq!(losses.len(), 3);
    assert!(losses.iter().all(|l| l.is_finite() && *l >= 0.0));
    // trainables moved
    let tr0 = p.identity_trainables(mode).unwrap();
    let moved = tr.iter().any(|(k, t)| {
        t.as_f32().unwrap() != tr0[k].as_f32().unwrap()
    });
    assert!(moved, "finetune did not update any trainable");

    let acc = p.quant_accuracy(mode, &stats, &tr, 100).unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn dws_rescale_preserves_fp_accuracy() {
    let (reg, artifacts) = need!(setup());
    let mut p =
        Pipeline::new(reg, &artifacts, "mobilenet_v2_mini").unwrap();
    let before = p.fp_accuracy(200).unwrap();
    let stats = p.calibrate(50).unwrap();
    let reports = p.dws_rescale(&stats).unwrap();
    assert!(!reports.is_empty());
    let after = p.fp_accuracy(200).unwrap();
    assert!(
        (before - after).abs() <= 0.01,
        "rescale changed FP accuracy: {before} -> {after}"
    );
}

#[test]
fn inject_spread_preserves_fp_and_hurts_scalar_quant() {
    let (reg, artifacts) = need!(setup());
    let mut p =
        Pipeline::new(reg.clone(), &artifacts, "mobilenet_v2_mini").unwrap();
    let fp_before = p.fp_accuracy(200).unwrap();
    let n = p
        .inject_spread(
            fat::coordinator::experiments::SPREAD_SEED,
            fat::coordinator::experiments::MOBILENET_SPREAD_LOG2,
        )
        .unwrap();
    assert!(n >= 5, "expected several DWS patterns, got {n}");
    let fp_after = p.fp_accuracy(200).unwrap();
    assert!(
        (fp_before - fp_after).abs() <= 0.01,
        "spread injection must be function-preserving: {fp_before} -> {fp_after}"
    );
    // scalar quantization now collapses relative to the clean model
    let stats = p.calibrate(50).unwrap();
    let tr0 = p.identity_trainables(QuantMode::SymScalar).unwrap();
    let q_spread = p
        .quant_accuracy(QuantMode::SymScalar, &stats, &tr0, 200)
        .unwrap();
    let p_clean = Pipeline::new(reg, &artifacts, "mobilenet_v2_mini").unwrap();
    let stats_c = p_clean.calibrate(50).unwrap();
    let q_clean = p_clean
        .quant_accuracy(QuantMode::SymScalar, &stats_c, &tr0, 200)
        .unwrap();
    assert!(
        q_spread < q_clean - 0.05,
        "spread should hurt scalar quant: {q_spread} vs clean {q_clean}"
    );
}

#[test]
fn int8_engine_agrees_with_fake_quant() {
    let (reg, artifacts) = need!(setup());
    let p = Pipeline::new(reg, &artifacts, "mnas_mini_10").unwrap();
    let mode = QuantMode::SymVector;
    let stats = p.calibrate(50).unwrap();
    let tr = p.identity_trainables(mode).unwrap();
    let fake = p.quant_accuracy(mode, &stats, &tr, 200).unwrap();
    let trained = p.trained_of_map(mode, &tr).unwrap();
    let qm = p.export_int8(mode, &stats, &trained).unwrap();
    let engine = Int8Engine::new(qm, EngineOptions::default());
    let acc =
        fat::coordinator::experiments::int8_accuracy(&engine, 200).unwrap();
    assert!(
        (fake - acc).abs() <= 0.08,
        "engine {acc} vs fake-quant {fake}"
    );
    assert!(engine.param_bytes() > 10_000);
}

/// The redesigned session path must be bit-exact with the legacy
/// `Pipeline` path for every mode: same calibration, same identity
/// thresholds, same exported integer model, same logits.
#[test]
fn session_matches_pipeline_bit_exact_per_mode() {
    let (reg, artifacts) = need!(setup());
    let p =
        Pipeline::new(reg.clone(), &artifacts, "mnas_mini_10").unwrap();
    let session =
        QuantSession::open(reg, &artifacts, "mnas_mini_10").unwrap();
    let stats = p.calibrate(50).unwrap();
    let cal = session.calibrate(CalibOpts::images(50)).unwrap();
    let (x, _) = fat::data::loader::batch(
        fat::data::Split::Val,
        &(0..20).collect::<Vec<_>>(),
    );
    for mode in QuantMode::all() {
        let legacy = p
            .export_int8(mode, &stats, &p.identity_trained(mode))
            .unwrap();
        let engine = cal
            .identity(&QuantSpec::from_mode(mode))
            .unwrap()
            .serve(EngineOptions::threads(2))
            .unwrap();
        let want = legacy.run_batch_with(&x, 1).unwrap();
        let got = engine.infer_batch(&x).unwrap();
        let (a, b) = (want.as_f32().unwrap(), got.as_f32().unwrap());
        assert_eq!(a.len(), b.len(), "{mode:?}");
        for i in 0..a.len() {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "{mode:?} logit {i}");
        }
    }
}

#[test]
fn asym_pipeline_runs() {
    let (reg, artifacts) = need!(setup());
    let p = Pipeline::new(reg, &artifacts, "mnas_mini_10").unwrap();
    let mode = QuantMode::AsymScalar;
    let stats = p.calibrate(50).unwrap();
    let mut cfg = PipelineConfig::default();
    cfg.max_steps = 2;
    cfg.epochs = 1;
    let (tr, losses) = p.finetune(mode, &stats, &cfg, |_, _, _| {}).unwrap();
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(tr.contains_key("act_at") && tr.contains_key("act_ar"));
    let acc = p.quant_accuracy(mode, &stats, &tr, 100).unwrap();
    assert!(acc > 0.15, "asym quant collapsed unexpectedly: {acc}");
}
