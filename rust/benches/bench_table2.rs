//! Table-2 pipeline bench: vector-mode stage costs (per-channel weight
//! thresholds make the train step marginally heavier than Table 1's
//! scalar mode — this harness quantifies that overhead).

use std::sync::Arc;

use fat::coordinator::experiments::{Ctx, TABLE_MODELS};
use fat::coordinator::PipelineConfig;
use fat::quant::export::QuantMode;
use fat::quant::session::{CalibOpts, QuantSpec};
use fat::runtime::{Registry, Runtime};
use fat::util::bench::{bench, BenchOpts};

fn main() {
    let artifacts = fat::artifacts_dir();
    if !artifacts.join("models/mobilenet_v2_mini").exists() {
        println!("SKIP table2 bench (run `make artifacts`)");
        return;
    }
    let ctx = Ctx::new(
        Arc::new(Registry::new(Arc::new(Runtime::cpu().unwrap()))),
        &artifacts,
    );
    let opts = BenchOpts { warmup: 0, iters: 3, max_secs: 120.0 };
    for model in TABLE_MODELS {
        let cal = ctx
            .session(model)
            .unwrap()
            .calibrate(CalibOpts::images(100))
            .unwrap();
        for mode in [QuantMode::SymVector, QuantMode::AsymVector] {
            let spec = QuantSpec::from_mode(mode);
            let th = cal.identity(&spec).unwrap();
            bench(
                &format!("t2_eval_500_{model}_{}", mode.name()),
                &opts,
                || {
                    std::hint::black_box(th.quant_accuracy(500).unwrap());
                },
            );
            let mut cfg = PipelineConfig::default();
            cfg.max_steps = 1;
            cfg.epochs = 1;
            let fopts = cfg.finetune_opts(false);
            bench(
                &format!("t2_finetune_step_{model}_{}", mode.name()),
                &opts,
                || {
                    std::hint::black_box(
                        cal.finetune(&spec, &fopts, |_, _, _| {})
                            .unwrap()
                            .losses()
                            .len(),
                    );
                },
            );
        }
    }
}
