//! int8 engine benchmarks (deployment simulator hot path): reference vs
//! cache-blocked GEMM, packed SIMD vs scalar kernels, autotuned vs
//! default GEMM blocking schedules, pooled-worker vs
//! per-call spawn sharding, thread-scaling at t ∈ {1,2,4,8}, im2col,
//! depthwise conv, and whole-model batch throughput. Every measurement
//! is also appended to a machine-readable `BENCH_int8.json`
//! (`FAT_BENCH_JSON` overrides the path) so the §Perf trajectory in
//! EXPERIMENTS.md is populated from real runs; raise `FAT_BENCH_ITERS`
//! for tighter timings.

use std::sync::Arc;

use fat::int8::engine::QLayer;
use fat::int8::kernels::{self, Blocking, Isa, PackedWeights};
use fat::int8::tune;
use fat::int8::serve::EngineOptions;
use fat::int8::{gemm, im2col, ops, qtensor::QTensor};
use fat::quant::export::QuantMode;
use fat::quant::scale::QParams;
use fat::quant::session::{CalibOpts, QuantSession, QuantSpec};
use fat::util::bench::{bench, bench_throughput, report_speedup, BenchLog, BenchOpts};
use fat::util::prop;
use fat::util::threads::fat_threads;

/// The PR-3 baseline sharding: spawn fresh OS threads per call via
/// `std::thread::scope` (kept here, benchmark-only, as the comparison
/// point for the persistent pool).
#[allow(clippy::too_many_arguments)]
fn gemm_spawn_sharded(
    a: &[i8],
    a_zp: i32,
    b: &[i8],
    bsums: &[i32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
    threads: usize,
) {
    let t = threads.max(1).min(m.max(1));
    if t <= 1 || n == 0 {
        return gemm::gemm_i8(a, a_zp, b, bsums, m, k, n, out);
    }
    let rows = m.div_ceil(t);
    std::thread::scope(|s| {
        for (i, out_slab) in out.chunks_mut(rows * n).enumerate() {
            let mc = out_slab.len() / n;
            let a_slab = &a[i * rows * k..i * rows * k + mc * k];
            s.spawn(move || {
                gemm::gemm_i8(a_slab, a_zp, b, bsums, mc, k, n, out_slab);
            });
        }
    });
}

fn main() {
    let opts = BenchOpts::from_env();
    let isa = Isa::detect();
    let mut log = BenchLog::default();
    println!(
        "FAT_THREADS default = {}, kernel ISA = {}",
        fat_threads(),
        isa.name()
    );

    // raw GEMM: a typical early-conv shape and a late, deeper one
    for &(m, k, n) in &[(1024usize, 144usize, 64usize), (512, 1152, 128)] {
        let a = prop::i8s(1, m * k);
        let b = prop::i8s(2, k * n);
        let sums = gemm::col_sums(&b, k, n);
        let pw = PackedWeights::pack(&b, k, n);
        let mut out = vec![0i32; m * n];
        let macs = m * k * n;
        let name = format!("gemm_i8_{m}x{k}x{n}");
        let shape = format!("{m}x{k}x{n}");
        bench_throughput(&format!("{name}_ref_macs"), &opts, macs, || {
            std::hint::black_box(gemm::gemm_ref(&a, -3, &b, m, k, n).len());
        });

        // unpacked blocked kernel (serves ad-hoc layers)
        let base =
            bench_throughput(&format!("{name}_t1_macs"), &opts, macs, || {
                gemm::gemm_i8(&a, -3, &b, &sums, m, k, n, &mut out);
                std::hint::black_box(out[0]);
            });
        log.add(&name, &shape, 1, "blocked-unpacked", base, macs);

        // packed kernels: scalar fallback vs the detected SIMD level
        let scalar = bench_throughput(
            &format!("{name}_packed_scalar_t1_macs"),
            &opts,
            macs,
            || {
                kernels::gemm_packed(
                    &a,
                    -3,
                    &pw,
                    &sums,
                    m,
                    &mut out,
                    Isa::Scalar,
                    Blocking::default(),
                );
                std::hint::black_box(out[0]);
            },
        );
        log.add(&name, &shape, 1, "scalar", scalar, macs);
        let simd = bench_throughput(
            &format!("{name}_packed_{}_t1_macs", isa.name()),
            &opts,
            macs,
            || {
                kernels::gemm_packed(
                    &a,
                    -3,
                    &pw,
                    &sums,
                    m,
                    &mut out,
                    isa,
                    Blocking::default(),
                );
                std::hint::black_box(out[0]);
            },
        );
        log.add(&name, &shape, 1, isa.name(), simd, macs);
        report_speedup(&format!("{name}_simd_vs_scalar"), scalar, simd);
        report_speedup(&format!("{name}_simd_vs_unpacked"), base, simd);

        // autotuned schedule vs the default (the blocking the tuner
        // would persist in a .fatm for this shape)
        let mut topts = tune::TuneOptions::full();
        topts.threads = 1;
        topts.isa = isa;
        let choice = tune::tune_gemm(&b, k, n, &topts, None);
        println!(
            "BENCH {name} tuned_blocking={} (default {})",
            choice.blocking.label(),
            Blocking::default().label()
        );
        let pw_tuned = PackedWeights::pack_with(&b, k, n, choice.blocking.nr);
        let tuned = bench_throughput(
            &format!("{name}_tuned_t1_macs"),
            &opts,
            macs,
            || {
                kernels::gemm_packed(
                    &a,
                    -3,
                    &pw_tuned,
                    &sums,
                    m,
                    &mut out,
                    isa,
                    choice.blocking,
                );
                std::hint::black_box(out[0]);
            },
        );
        log.add(&name, &shape, 1, &format!("tuned-{}", isa.name()), tuned, macs);
        report_speedup(&format!("{name}_tuned_vs_default_t1"), simd, tuned);

        // int4 nibble panels (half the weight bytes per strip): same
        // shape, weights narrowed to the int4 range
        let b4: Vec<i8> = b.iter().map(|&v| v % 8).collect();
        let sums4 = gemm::col_sums(&b4, k, n);
        let pw4 = PackedWeights::pack_bits(&b4, k, n, kernels::NR, 4);
        let int4 = bench_throughput(
            &format!("{name}_int4_{}_t1_macs", isa.name()),
            &opts,
            macs,
            || {
                kernels::gemm_packed(
                    &a,
                    -3,
                    &pw4,
                    &sums4,
                    m,
                    &mut out,
                    isa,
                    Blocking::default(),
                );
                std::hint::black_box(out[0]);
            },
        );
        log.add(&name, &shape, 1, &format!("int4-{}", isa.name()), int4, macs);
        report_speedup(&format!("{name}_int4_vs_int8_t1"), simd, int4);

        // pooled sharding vs the PR-3 per-call spawn baseline
        for t in [2usize, 4, 8] {
            let spawn = bench_throughput(
                &format!("{name}_spawn_t{t}_macs"),
                &opts,
                macs,
                || {
                    gemm_spawn_sharded(
                        &a, -3, &b, &sums, m, k, n, &mut out, t,
                    );
                    std::hint::black_box(out[0]);
                },
            );
            log.add(&name, &shape, t, "spawn", spawn, macs);
            let pooled = bench_throughput(
                &format!("{name}_pooled_t{t}_macs"),
                &opts,
                macs,
                || {
                    kernels::gemm_packed_parallel(
                        &a,
                        -3,
                        &pw,
                        &sums,
                        m,
                        &mut out,
                        t,
                        isa,
                        Blocking::default(),
                    );
                    std::hint::black_box(out[0]);
                },
            );
            log.add(&name, &shape, t, &format!("pooled-{}", isa.name()), pooled, macs);
            report_speedup(&format!("{name}_pooled_vs_spawn_t{t}"), spawn, pooled);
            report_speedup(&format!("{name}_pooled_t{t}_vs_t1"), simd, pooled);
        }
    }

    // im2col for a 32x32x16 image: 3x3 general path and the 1x1 pure-copy
    // fast path (pointwise convs skip the zero-point prefill entirely)
    let x = prop::i8s(3, 32 * 32 * 16);
    let mut patches = Vec::new();
    let i2c = bench("im2col_32x32x16_k3", &opts, || {
        let (oh, _) =
            im2col::im2col_into(&x, 1, 32, 32, 16, 3, 1, 0, &mut patches);
        std::hint::black_box(oh);
    });
    log.add("im2col_k3", "32x32x16", 1, "scalar", i2c, 32 * 32 * 16 * 9);
    let i2c1 = bench("im2col_32x32x16_k1_copy", &opts, || {
        let (oh, _) =
            im2col::im2col_into(&x, 1, 32, 32, 16, 1, 1, 0, &mut patches);
        std::hint::black_box(oh);
    });
    log.add("im2col_k1", "32x32x16", 1, "copy", i2c1, 32 * 32 * 16);

    // dwconv 3x3 over 32x32x64: scalar vs SIMD taps, serial vs pooled
    let qp = QParams::symmetric_signed(1.0);
    let xq = QTensor {
        shape: vec![1, 32, 32, 64],
        data: prop::i8s(4, 32 * 32 * 64),
        qp,
    };
    let l = QLayer {
        w_q: prop::i8s(5, 9 * 64).into(),
        w_sums: vec![],
        bias_q: vec![0i32; 64],
        requant: vec![fat::quant::scale::quantize_multiplier(0.001); 64],
        requant_shift: None,
        out_qp: qp,
        clamp: (-127, 127),
        w_scales: vec![1.0],
        packed: None,
        blocking: Blocking::default(),
        fused: false,
    };
    let dw_macs = 32 * 32 * 64 * 9;
    let mut dw_scalar = 0.0;
    for t in [1usize, 4] {
        let mut ctx =
            ops::OpCtx { isa: Isa::Scalar, threads: t, ..Default::default() };
        let s = bench(&format!("dwconv_32x32x64_k3_scalar_t{t}"), &opts, || {
            let y = ops::dwconv2d(&xq, &l, 3, 1, &mut ctx, Vec::new());
            std::hint::black_box(y.data[0]);
        });
        log.add("dwconv_k3", "32x32x64", t, "scalar", s, dw_macs);
        if t == 1 {
            dw_scalar = s;
        }
        let mut ctx = ops::OpCtx::with_threads(t);
        let v = bench(
            &format!("dwconv_32x32x64_k3_{}_t{t}", isa.name()),
            &opts,
            || {
                let y = ops::dwconv2d(&xq, &l, 3, 1, &mut ctx, Vec::new());
                std::hint::black_box(y.data[0]);
            },
        );
        log.add("dwconv_k3", "32x32x64", t, isa.name(), v, dw_macs);
        if t == 1 {
            report_speedup("dwconv_simd_vs_scalar_t1", dw_scalar, v);
        }
    }

    // fused implicit-GEMM conv vs the staged im2col pipeline (ISSUE-10):
    // identical packed panels and epilogue constants — the fused path
    // skips the patch-matrix materialization and the i32 accumulator
    // round-trip, so the gap is pure memory traffic
    {
        let qp = QParams::symmetric_signed(1.0);
        for &(h, w, c, cout, k) in &[
            (32usize, 32usize, 16usize, 32usize, 3usize),
            (14, 14, 128, 128, 3),
            (28, 28, 64, 64, 1),
        ] {
            let kk = k * k * c;
            let xq = QTensor {
                shape: vec![1, h, w, c],
                data: prop::i8s(7, h * w * c),
                qp,
            };
            let wq = prop::i8s(8, kk * cout);
            let sums = gemm::col_sums(&wq, kk, cout);
            let pw = PackedWeights::pack(&wq, kk, cout);
            let mk = |fused: bool| QLayer {
                w_q: wq.clone().into(),
                w_sums: sums.clone(),
                bias_q: vec![3i32; cout],
                requant: vec![
                    fat::quant::scale::quantize_multiplier(0.001);
                    cout
                ],
                requant_shift: None,
                out_qp: qp,
                clamp: (-127, 127),
                w_scales: vec![1.0],
                packed: Some(pw.clone()),
                blocking: Blocking::default(),
                fused,
            };
            let staged_l = mk(false);
            let fused_l = mk(true);
            let macs = h * w * kk * cout; // stride-1 SAME: m = h·w
            let name = format!("conv_k{k}_{h}x{w}x{c}to{cout}");
            let shape = format!("{h}x{w}x{c}->{cout}");
            for t in [1usize, 4] {
                let mut ctx = ops::OpCtx::with_threads(t);
                let staged = bench_throughput(
                    &format!("{name}_staged_t{t}_macs"),
                    &opts,
                    macs,
                    || {
                        let y = ops::conv2d(
                            &xq, &staged_l, k, 1, cout, &mut ctx,
                            Vec::new(),
                        );
                        std::hint::black_box(y.data[0]);
                    },
                );
                log.add(
                    &name,
                    &shape,
                    t,
                    &format!("staged-{}", isa.name()),
                    staged,
                    macs,
                );
                let fused = bench_throughput(
                    &format!("{name}_fused_t{t}_macs"),
                    &opts,
                    macs,
                    || {
                        let y = ops::conv2d_fused(
                            &xq, &fused_l, k, 1, cout, &mut ctx,
                            Vec::new(), None,
                        );
                        std::hint::black_box(y.data[0]);
                    },
                );
                log.add(
                    &name,
                    &shape,
                    t,
                    &format!("fused-{}", isa.name()),
                    fused,
                    macs,
                );
                report_speedup(
                    &format!("{name}_fused_vs_staged_t{t}"),
                    staged,
                    fused,
                );
            }
        }
    }

    // requant epilogue: gemmlowp fixed-point multiplier vs the pow2
    // shift-only path, over a typical late-conv accumulator slab
    {
        let (pix, cout) = (1024usize, 64usize);
        let acc: Vec<i32> = prop::i8s(6, pix * cout)
            .into_iter()
            .map(|v| v as i32 * 513)
            .collect();
        let bias = vec![17i32; cout];
        let shift: Vec<i32> = (0..cout).map(|c| 5 + (c % 4) as i32).collect();
        let requant: Vec<(i32, i32)> =
            shift.iter().map(|&s| (1 << 30, s - 1)).collect();
        let mut out8 = Vec::new();
        let n = acc.len();
        let mul = bench_throughput("requant_mul_1024x64", &opts, n, || {
            ops::requant_store(
                &acc,
                &bias,
                &requant,
                qp,
                (-128, 127),
                cout,
                &mut out8,
            );
            std::hint::black_box(out8[0]);
        });
        log.add("requant_epilogue", "1024x64", 1, "mul", mul, n);
        let sh = bench_throughput(
            &format!("requant_shift_{}_1024x64", isa.name()),
            &opts,
            n,
            || {
                ops::requant_store_shift(
                    &acc,
                    &bias,
                    &shift,
                    qp,
                    (-128, 127),
                    cout,
                    &mut out8,
                    isa,
                );
                std::hint::black_box(out8[0]);
            },
        );
        log.add(
            "requant_epilogue",
            "1024x64",
            1,
            &format!("shift-{}", isa.name()),
            sh,
            n,
        );
        report_speedup("requant_shift_vs_mul", mul, sh);
    }

    // whole-model throughput (needs the artifact model dir for the
    // pretrained weights; the float side of the export runs on whichever
    // backend resolves — PJRT with the `pjrt` feature, native otherwise.
    // The engine numbers below measure the int8 plan either way.)
    let artifacts = fat::artifacts_dir();
    if artifacts.join("models/mobilenet_v2_mini").exists() {
        let rt = match fat::runtime::Runtime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                println!("SKIP int8 whole-model bench ({e})");
                finish(&log);
                return;
            }
        };
        let reg = Arc::new(fat::runtime::Registry::new(Arc::new(rt)));
        let th = QuantSession::open(reg, &artifacts, "mobilenet_v2_mini")
            .unwrap()
            .calibrate(CalibOpts::images(25))
            .unwrap()
            .identity(&QuantSpec::from_mode(QuantMode::SymVector))
            .unwrap();
        let qm = th.export().unwrap();
        // wrap the same compiled model (don't export twice) so the
        // fresh-vs-pooled comparison below runs identical plans
        let engine =
            fat::int8::Int8Engine::new(qm.clone(), EngineOptions::default());
        let (x, _) = fat::data::loader::batch(
            fat::data::Split::Val,
            &(0..50).collect::<Vec<_>>(),
        );
        let mut base = 0.0;
        for t in [1usize, 2, 4] {
            let mean = bench_throughput(
                &format!("int8_mobilenet_batch50_t{t}"),
                &opts,
                50,
                || {
                    std::hint::black_box(
                        engine.infer_batch_with(&x, t).unwrap().len(),
                    );
                },
            );
            log.add("int8_mobilenet", "batch50", t, isa.name(), mean, 50);
            if t == 1 {
                base = mean;
            } else {
                report_speedup(
                    &format!("int8_mobilenet_batch50_t{t}_vs_t1"),
                    base,
                    mean,
                );
            }
        }

        // engine-handle overhead: a fresh ExecState per call (bare
        // QModel::run_batch_with) vs the handle's pooled per-worker
        // states (Int8Engine::infer_batch_with)
        for t in [1usize, 4] {
            let fresh = bench_throughput(
                &format!("int8_mobilenet_fresh_state_t{t}"),
                &opts,
                50,
                || {
                    std::hint::black_box(
                        qm.run_batch_with(&x, t).unwrap().len(),
                    );
                },
            );
            let pooled = bench_throughput(
                &format!("int8_mobilenet_pooled_state_t{t}"),
                &opts,
                50,
                || {
                    std::hint::black_box(
                        engine.infer_batch_with(&x, t).unwrap().len(),
                    );
                },
            );
            report_speedup(
                &format!("int8_mobilenet_pooled_vs_fresh_t{t}"),
                fresh,
                pooled,
            );
        }
        println!(
            "engine pool: {} resting state(s) after the sweep",
            engine.pooled_states()
        );
    } else {
        println!("SKIP int8 whole-model bench (run `make artifacts`)");
    }
    finish(&log);
}

fn finish(log: &BenchLog) {
    let path = std::env::var("FAT_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_int8.json".to_string());
    if let Err(e) = log.write(&path) {
        println!("BENCH log write failed ({path}): {e}");
    }
}
