//! int8 engine benchmarks (deployment simulator hot path): GEMM, im2col,
//! per-op kernels and whole-model throughput. The §Perf optimization log
//! in EXPERIMENTS.md tracks these numbers.

use std::sync::Arc;

use fat::int8::{gemm, im2col, qtensor::QTensor};
use fat::quant::export::QuantMode;
use fat::quant::scale::QParams;
use fat::util::bench::{bench, bench_throughput, BenchOpts};
use fat::util::prop;

fn main() {
    let opts = BenchOpts { warmup: 1, iters: 10, max_secs: 30.0 };

    // raw GEMM: (1024, 144) x (144, 64) — a typical conv layer shape
    let (m, k, n) = (1024, 144, 64);
    let a = prop::i8s(1, m * k);
    let b = prop::i8s(2, k * n);
    let sums = gemm::col_sums(&b, k, n);
    let mut out = vec![0i32; m * n];
    let macs = m * k * n;
    bench_throughput("gemm_i8_1024x144x64_macs", &opts, macs, || {
        gemm::gemm_i8(&a, -3, &b, &sums, m, k, n, &mut out);
        std::hint::black_box(out[0]);
    });

    // im2col for a 32x32x16 image, 3x3
    let x = prop::i8s(3, 32 * 32 * 16);
    bench("im2col_32x32x16_k3", &opts, || {
        let (p, _, _) = im2col::im2col_i8(&x, 1, 32, 32, 16, 3, 1, 0);
        std::hint::black_box(p.len());
    });

    // dwconv 3x3 over 32x32x64
    let qp = QParams::symmetric_signed(1.0);
    let xq = QTensor {
        shape: vec![1, 32, 32, 64],
        data: prop::i8s(4, 32 * 32 * 64),
        qp,
    };
    let wq = prop::i8s(5, 9 * 64);
    let bias = vec![0i32; 64];
    let req = vec![fat::quant::scale::quantize_multiplier(0.001); 64];
    bench("dwconv_32x32x64_k3", &opts, || {
        let y = fat::int8::ops::dwconv2d(
            &xq, &wq, &bias, &req, qp, (-127, 127), 3, 1,
        );
        std::hint::black_box(y.data[0]);
    });

    // whole-model throughput (needs artifacts)
    let artifacts = fat::artifacts_dir();
    if artifacts.join("models/mobilenet_v2_mini").exists() {
        let rt = fat::runtime::Runtime::cpu().unwrap();
        let reg = Arc::new(fat::runtime::Registry::new(Arc::new(rt)));
        let p = fat::coordinator::Pipeline::new(
            reg,
            &artifacts,
            "mobilenet_v2_mini",
        )
        .unwrap();
        let stats = p.calibrate(25).unwrap();
        let trained = p.identity_trained(QuantMode::SymVector);
        let qm = p
            .export_int8(QuantMode::SymVector, &stats, &trained)
            .unwrap();
        let (x, _) = fat::data::loader::batch(
            fat::data::Split::Val,
            &(0..50).collect::<Vec<_>>(),
        );
        bench_throughput("int8_mobilenet_batch50", &opts, 50, || {
            std::hint::black_box(qm.run_batch(&x).unwrap().len());
        });
    } else {
        println!("SKIP int8 whole-model bench (run `make artifacts`)");
    }
}
