//! int8 engine benchmarks (deployment simulator hot path): reference vs
//! cache-blocked GEMM, thread-scaling at FAT_THREADS ∈ {1,2,4,8}, im2col,
//! depthwise conv, and whole-model batch throughput. The §Perf
//! optimization log in EXPERIMENTS.md tracks these numbers; raise
//! FAT_BENCH_ITERS for tighter timings.

use std::sync::Arc;

use fat::int8::engine::QLayer;
use fat::int8::serve::EngineOptions;
use fat::int8::{gemm, im2col, ops, qtensor::QTensor};
use fat::quant::export::QuantMode;
use fat::quant::scale::QParams;
use fat::quant::session::{CalibOpts, QuantSession, QuantSpec};
use fat::util::bench::{bench, bench_throughput, report_speedup, BenchOpts};
use fat::util::prop;
use fat::util::threads::fat_threads;

fn main() {
    let opts = BenchOpts::from_env();
    println!("FAT_THREADS default = {}", fat_threads());

    // raw GEMM: a typical early-conv shape and a late, deeper one
    for &(m, k, n) in &[(1024usize, 144usize, 64usize), (512, 1152, 128)] {
        let a = prop::i8s(1, m * k);
        let b = prop::i8s(2, k * n);
        let sums = gemm::col_sums(&b, k, n);
        let mut out = vec![0i32; m * n];
        let macs = m * k * n;
        let name = format!("gemm_i8_{m}x{k}x{n}");
        bench_throughput(&format!("{name}_ref_macs"), &opts, macs, || {
            std::hint::black_box(gemm::gemm_ref(&a, -3, &b, m, k, n).len());
        });
        let base =
            bench_throughput(&format!("{name}_t1_macs"), &opts, macs, || {
                gemm::gemm_i8(&a, -3, &b, &sums, m, k, n, &mut out);
                std::hint::black_box(out[0]);
            });
        for t in [2usize, 4, 8] {
            let mean = bench_throughput(
                &format!("{name}_t{t}_macs"),
                &opts,
                macs,
                || {
                    gemm::gemm_i8_parallel(
                        &a, -3, &b, &sums, m, k, n, &mut out, t,
                    );
                    std::hint::black_box(out[0]);
                },
            );
            report_speedup(&format!("{name}_t{t}_vs_t1"), base, mean);
        }
    }

    // im2col for a 32x32x16 image, 3x3 (with scratch reuse)
    let x = prop::i8s(3, 32 * 32 * 16);
    let mut patches = Vec::new();
    bench("im2col_32x32x16_k3", &opts, || {
        let (oh, _) =
            im2col::im2col_into(&x, 1, 32, 32, 16, 3, 1, 0, &mut patches);
        std::hint::black_box(oh);
    });

    // dwconv 3x3 over 32x32x64, serial vs row-sharded
    let qp = QParams::symmetric_signed(1.0);
    let xq = QTensor {
        shape: vec![1, 32, 32, 64],
        data: prop::i8s(4, 32 * 32 * 64),
        qp,
    };
    let l = QLayer {
        w_q: prop::i8s(5, 9 * 64),
        w_sums: vec![],
        bias_q: vec![0i32; 64],
        requant: vec![fat::quant::scale::quantize_multiplier(0.001); 64],
        out_qp: qp,
        clamp: (-127, 127),
        w_scales: vec![1.0],
    };
    for t in [1usize, 4] {
        let mut ctx = ops::OpCtx::with_threads(t);
        bench(&format!("dwconv_32x32x64_k3_t{t}"), &opts, || {
            let y = ops::dwconv2d(&xq, &l, 3, 1, &mut ctx, Vec::new());
            std::hint::black_box(y.data[0]);
        });
    }

    // whole-model throughput (needs the artifact model dir for the
    // pretrained weights; the float side of the export runs on whichever
    // backend resolves — PJRT with the `pjrt` feature, native otherwise.
    // The engine numbers below measure the int8 plan either way.)
    let artifacts = fat::artifacts_dir();
    if artifacts.join("models/mobilenet_v2_mini").exists() {
        let rt = match fat::runtime::Runtime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                println!("SKIP int8 whole-model bench ({e})");
                return;
            }
        };
        let reg = Arc::new(fat::runtime::Registry::new(Arc::new(rt)));
        let th = QuantSession::open(reg, &artifacts, "mobilenet_v2_mini")
            .unwrap()
            .calibrate(CalibOpts::images(25))
            .unwrap()
            .identity(&QuantSpec::from_mode(QuantMode::SymVector))
            .unwrap();
        let qm = th.export().unwrap();
        // wrap the same compiled model (don't export twice) so the
        // fresh-vs-pooled comparison below runs identical plans
        let engine =
            fat::int8::Int8Engine::new(qm.clone(), EngineOptions::default());
        let (x, _) = fat::data::loader::batch(
            fat::data::Split::Val,
            &(0..50).collect::<Vec<_>>(),
        );
        let mut base = 0.0;
        for t in [1usize, 2, 4] {
            let mean = bench_throughput(
                &format!("int8_mobilenet_batch50_t{t}"),
                &opts,
                50,
                || {
                    std::hint::black_box(
                        engine.infer_batch_with(&x, t).unwrap().len(),
                    );
                },
            );
            if t == 1 {
                base = mean;
            } else {
                report_speedup(
                    &format!("int8_mobilenet_batch50_t{t}_vs_t1"),
                    base,
                    mean,
                );
            }
        }

        // engine-handle overhead: a fresh ExecState per call (bare
        // QModel::run_batch_with) vs the handle's pooled per-worker
        // states (Int8Engine::infer_batch_with)
        for t in [1usize, 4] {
            let fresh = bench_throughput(
                &format!("int8_mobilenet_fresh_state_t{t}"),
                &opts,
                50,
                || {
                    std::hint::black_box(
                        qm.run_batch_with(&x, t).unwrap().len(),
                    );
                },
            );
            let pooled = bench_throughput(
                &format!("int8_mobilenet_pooled_state_t{t}"),
                &opts,
                50,
                || {
                    std::hint::black_box(
                        engine.infer_batch_with(&x, t).unwrap().len(),
                    );
                },
            );
            report_speedup(
                &format!("int8_mobilenet_pooled_vs_fresh_t{t}"),
                fresh,
                pooled,
            );
        }
        println!(
            "engine pool: {} resting state(s) after the sweep",
            engine.pooled_states()
        );
    } else {
        println!("SKIP int8 whole-model bench (run `make artifacts`)");
    }
}
