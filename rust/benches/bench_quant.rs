//! Quant-substrate benchmarks: BN fold, weight quantization, fake-quant
//! hot loop, §3.3 rescale, calibrators.

use fat::model::ModelStore;
use fat::quant::calibrate::{kl_threshold, percentile_threshold};
use fat::quant::scale::QParams;
use fat::quant::{dws, fold};
use fat::tensor::Tensor;
use fat::util::bench::{bench, bench_throughput, BenchOpts};
use fat::util::prop;

fn main() {
    let opts = BenchOpts { warmup: 1, iters: 10, max_secs: 20.0 };

    // fake-quant hot loop over 1M values
    let xs = prop::f32s(1, 1 << 20, -3.0, 3.0);
    let qp = QParams::symmetric_signed(2.5);
    bench_throughput("fake_quant_1M", &opts, xs.len(), || {
        let mut acc = 0f32;
        for &v in &xs {
            acc += qp.fake_quant(v);
        }
        std::hint::black_box(acc);
    });

    // weight quantization per mode
    let w = Tensor::f32(vec![3, 3, 64, 128], prop::f32s(2, 3 * 3 * 64 * 128, -1.0, 1.0));
    bench("quantize_weights_scalar_74k", &opts, || {
        let r = fat::quant::export::quantize_weights(&w, 128, false, &[1.0]);
        std::hint::black_box(r.unwrap().0.len());
    });
    bench("quantize_weights_vector_74k", &opts, || {
        let r = fat::quant::export::quantize_weights(
            &w,
            128,
            true,
            &vec![1.0; 128],
        );
        std::hint::black_box(r.unwrap().0.len());
    });

    // calibrators on a 128-bin histogram
    let hist: Vec<u32> = (0..128)
        .map(|i| {
            let x = -4.0 + 8.0 * (i as f32 + 0.5) / 128.0;
            (1e5 * (-x * x / 2.0).exp()) as u32
        })
        .collect();
    bench("calibrator_percentile", &opts, || {
        std::hint::black_box(percentile_threshold(&hist, -4.0, 4.0, 9990));
    });
    bench("calibrator_kl", &opts, || {
        std::hint::black_box(kl_threshold(&hist, -4.0, 4.0));
    });

    // artifact-dependent: BN fold + §3.3 over the real model
    let artifacts = fat::artifacts_dir();
    if artifacts.join("models/mobilenet_v2_mini").exists() {
        let store =
            ModelStore::open(&artifacts, "mobilenet_v2_mini").unwrap();
        let g = store.graph().unwrap();
        let raw = store.raw_weights().unwrap();
        bench("bn_fold_mobilenet", &opts, || {
            std::hint::black_box(fold::fold_bn(&g, &raw).unwrap().len());
        });

        let fg = store.folded_graph().unwrap();
        let folded = fold::fold_bn(&g, &raw).unwrap();
        let ch_max: std::collections::BTreeMap<String, Vec<f32>> =
            fat::quant::dws::find_patterns(&fg)
                .iter()
                .map(|p| {
                    let c = fg.node(&p.dw).unwrap().ch;
                    (p.dw.clone(), vec![3.0; c])
                })
                .collect();
        bench("dws_rescale_mobilenet", &opts, || {
            let mut w = folded.clone();
            std::hint::black_box(
                dws::rescale_model(&fg, &mut w, &ch_max).unwrap().len(),
            );
        });
    } else {
        println!("SKIP artifact-dependent quant benches (run `make artifacts`)");
    }
}
