//! Data-substrate benchmarks: SynthShapes generation + batcher throughput.
//! (Plain-binary harness; criterion is unavailable on this offline box.)

use fat::data::{loader, synth, Split};
use fat::util::bench::{bench_throughput, BenchOpts};

fn main() {
    let opts = BenchOpts { warmup: 1, iters: 5, max_secs: 20.0 };

    let idx: Vec<u64> = (0..256).collect();
    bench_throughput("synth_generate_256", &opts, 256, || {
        let (img, _) = synth::generate(synth::SEED_TRAIN, &idx);
        std::hint::black_box(img.len());
    });

    let batcher = loader::Batcher::new(Split::Train, (0..320).collect(), 32)
        .shuffled(7);
    bench_throughput("batcher_epoch_320", &opts, 320, || {
        for (x, _) in batcher.epoch_iter(0) {
            std::hint::black_box(x.len());
        }
    });

    bench_throughput("shuffle_12k", &opts, 12_000, || {
        let mut v: Vec<u64> = (0..12_000).collect();
        loader::shuffle(&mut v, 3, 1);
        std::hint::black_box(v[0]);
    });
}
