//! PJRT runtime benchmarks: artifact dispatch overhead, marshalling cost,
//! fp_forward/quant_fwd/train_step latency. These bound the L3 hot loop —
//! the fine-tune step time is the paper-pipeline's unit of work.

use std::sync::Arc;

use fat::coordinator::finetune::init_trainables;
use fat::coordinator::marshal::{build_inputs, Group};
use fat::model::ModelStore;
use fat::runtime::{Registry, Runtime};
use fat::util::bench::{bench, bench_throughput, BenchOpts};

fn main() {
    let artifacts = fat::artifacts_dir();
    if !artifacts.join("models/mobilenet_v2_mini").exists() {
        println!("SKIP runtime benches (run `make artifacts`)");
        return;
    }
    if !fat::runtime::pjrt_available() {
        println!(
            "SKIP runtime benches (no `pjrt` feature; see bench_finetune \
             for the native backend)"
        );
        return;
    }
    let opts = BenchOpts { warmup: 1, iters: 8, max_secs: 60.0 };
    let rt = match Runtime::cpu() {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!("SKIP runtime benches ({e})");
            return;
        }
    };
    let reg = Arc::new(Registry::new(rt));
    let model = "mobilenet_v2_mini";
    let store = ModelStore::open(&artifacts, model).unwrap();
    let raw_graph = store.graph().unwrap();
    let weights =
        fat::quant::fold::fold_bn(&raw_graph, &store.raw_weights().unwrap())
            .unwrap();

    // fp_forward (batch 100)
    let art = reg.get(store.artifact_path("fp_forward")).unwrap();
    let (x, _) = fat::data::loader::batch(
        fat::data::Split::Val,
        &(0..100).collect::<Vec<_>>(),
    );
    let inputs =
        build_inputs(&art.manifest, &[Group::Map(&weights), Group::Single(&x)])
            .unwrap();
    bench_throughput("fp_forward_b100", &opts, 100, || {
        std::hint::black_box(art.execute(&inputs).unwrap().len());
    });

    // marshalling alone (literal creation dominates dispatch overhead)
    bench("marshal_build_inputs_fp", &opts, || {
        std::hint::black_box(
            build_inputs(
                &art.manifest,
                &[Group::Map(&weights), Group::Single(&x)],
            )
            .unwrap()
            .len(),
        );
    });

    // quant forward (sym_vector, batch 100)
    let qart = reg.get(store.artifact_path("quant_fwd_sym_vector")).unwrap();
    let ts = reg.get(store.artifact_path("train_step_sym_vector")).unwrap();
    let tr = init_trainables(&ts);
    let act_t = fat::tensor::Tensor::f32(
        vec![store.sites().unwrap().sites.len(), 2],
        store
            .sites()
            .unwrap()
            .sites
            .iter()
            .flat_map(|_| [0.0f32, 3.0])
            .collect(),
    );
    let qinputs = build_inputs(
        &qart.manifest,
        &[
            Group::Map(&weights),
            Group::Single(&act_t),
            Group::Map(&tr),
            Group::Single(&x),
        ],
    )
    .unwrap();
    bench_throughput("quant_fwd_sym_vector_b100", &opts, 100, || {
        std::hint::black_box(qart.execute(&qinputs).unwrap().len());
    });

    // train step (batch 32) — the fine-tune unit of work
    let (xb, _) = fat::data::loader::batch(
        fat::data::Split::Train,
        &(0..32).collect::<Vec<_>>(),
    );
    let m: std::collections::BTreeMap<_, _> = tr
        .iter()
        .map(|(k, t)| {
            (k.clone(), fat::tensor::Tensor::zeros_f32(t.shape.clone()))
        })
        .collect();
    let step = fat::tensor::Tensor::scalar_f32(1.0);
    let lr = fat::tensor::Tensor::scalar_f32(0.01);
    let tinputs = build_inputs(
        &ts.manifest,
        &[
            Group::Map(&weights),
            Group::Single(&act_t),
            Group::Map(&tr),
            Group::Map(&m),
            Group::Map(&m),
            Group::Single(&step),
            Group::Single(&lr),
            Group::Single(&xb),
        ],
    )
    .unwrap();
    let topts = BenchOpts { warmup: 1, iters: 5, max_secs: 60.0 };
    bench("train_step_sym_vector_b32", &topts, || {
        std::hint::black_box(ts.execute(&tinputs).unwrap().len());
    });
}
