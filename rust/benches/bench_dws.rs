//! §4.2 / §3.3 bench: DWS pattern matching, scale computation, full
//! rescale, spread injection and the point-wise fine-tune step — the
//! moving parts behind the dws_ladder experiment.

use std::sync::Arc;

use fat::coordinator::experiments::{MOBILENET_SPREAD_LOG2, SPREAD_SEED};
use fat::coordinator::PipelineConfig;
use fat::quant::dws;
use fat::quant::session::{CalibOpts, QuantSession};
use fat::runtime::{Registry, Runtime};
use fat::util::bench::{bench, BenchOpts};

fn main() {
    let artifacts = fat::artifacts_dir();
    if !artifacts.join("models/mobilenet_v2_mini").exists() {
        println!("SKIP dws bench (run `make artifacts`)");
        return;
    }
    let opts = BenchOpts { warmup: 1, iters: 10, max_secs: 60.0 };
    let reg = Arc::new(Registry::new(Arc::new(Runtime::cpu().unwrap())));
    let session =
        QuantSession::open(reg, &artifacts, "mobilenet_v2_mini").unwrap();
    let core = session.core();

    bench("dws_find_patterns", &opts, || {
        std::hint::black_box(dws::find_patterns(&core.graph).len());
    });

    let cal = session.calibrate(CalibOpts::images(50)).unwrap();
    let ch_max: std::collections::BTreeMap<String, Vec<f32>> = cal
        .stats()
        .channel_minmax
        .iter()
        .map(|(k, v)| (k.clone(), v.iter().map(|m| m.max).collect()))
        .collect();
    bench("dws_rescale_model", &opts, || {
        let mut w = core.weights.clone();
        std::hint::black_box(
            dws::rescale_model(&core.graph, &mut w, &ch_max).unwrap().len(),
        );
    });

    bench("dws_inject_spread", &opts, || {
        let mut w = core.weights.clone();
        std::hint::black_box(
            dws::inject_spread(
                &core.graph,
                &mut w,
                SPREAD_SEED,
                MOBILENET_SPREAD_LOG2,
            )
            .unwrap(),
        );
    });

    // point-wise fine-tune step (the §4.2 rung-2 unit of work) — this
    // stage is artifact-only (no native implementation), so skip it
    // when the float backend is native
    if !fat::runtime::pjrt_available() {
        println!("SKIP pointwise_finetune_step (needs the `pjrt` feature)");
        return;
    }
    let mut cfg = PipelineConfig::default();
    cfg.max_steps = 1;
    cfg.epochs = 1;
    let fopts = cfg.finetune_opts(true);
    let spec = fat::quant::QuantSpec::default(); // max calibrator
    let sopts = BenchOpts { warmup: 1, iters: 3, max_secs: 60.0 };
    bench("pointwise_finetune_step", &sopts, || {
        std::hint::black_box(
            cal.finetune_pointwise(&spec, &fopts, |_, _, _| {})
                .unwrap()
                .1
                .len(),
        );
    });
}
