//! Serving cold-start benchmark (DESIGN.md §11, EXPERIMENTS.md §Perf
//! PR-7): what a `.fatm` artifact saves over compiling from scratch.
//! Baseline is the in-process export path — `build_qmodel` re-quantizes
//! weights, re-derives qparams and re-packs every SIMD panel on every
//! process start. Variants load the same compiled model from a `.fatm`
//! file: zero-copy mmap and heap-read, plus load-to-first-inference
//! latency (the number a deploy actually waits on). Loaded models are
//! checked bit-exact against the in-memory export before anything is
//! timed. Measurements land in `BENCH_load.json` (`FAT_BENCH_JSON`
//! overrides the path); raise `FAT_BENCH_ITERS` to lengthen the runs.

use std::collections::BTreeMap;
use std::hint::black_box;

use fat::artifact::{self, LoadOptions};
use fat::int8::{Isa, QModel, QTensor};
use fat::model::builtin;
use fat::quant::calibrate::CalibStats;
use fat::quant::export::{build_qmodel, QuantMode, Trained};
use fat::tensor::Tensor;
use fat::util::bench::{bench, report_speedup, BenchLog, BenchOpts};

/// The from-scratch cold-start path a `.fatm` artifact replaces:
/// builtin graph + weights through `build_qmodel` (quantize, fold,
/// col-sum, prepack) with deterministic synthetic calibration ranges.
fn build(name: &str) -> QModel {
    let (g, s, w): (_, _, BTreeMap<String, Tensor>) =
        builtin::load(name).unwrap();
    let mut st = CalibStats::new(s.sites.len());
    for (i, site) in s.sites.iter().enumerate() {
        let lo = if site.unsigned { 0.0 } else { -2.0 - 0.1 * i as f32 };
        st.site_minmax[i].update(lo, 2.5 + 0.2 * i as f32);
    }
    st.batches = 1;
    let tr = Trained::identity(&g, QuantMode::SymVector, s.sites.len());
    build_qmodel(&g, &w, &s, &st, QuantMode::SymVector, &tr).unwrap()
}

fn quant_input(qm: &QModel) -> QTensor {
    let sh = qm
        .graph
        .nodes
        .iter()
        .find(|n| n.op == fat::model::Op::Input)
        .and_then(|n| n.input_shape.clone())
        .expect("builtin model has a shaped input");
    let per_img: usize = sh.iter().product();
    let x: Vec<f32> = (0..per_img)
        .map(|i| ((i * 37 + 5) % 256) as f32 / 255.0)
        .collect();
    QTensor::quantize(vec![1, sh[0], sh[1], sh[2]], &x, qm.input_qp)
}

fn main() {
    let opts = BenchOpts::from_env();
    let isa = Isa::detect();
    let dir = std::env::temp_dir()
        .join(format!("fatm_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut log = BenchLog::default();

    for name in ["tiny_cnn", "mobilenet_v2_mini"] {
        let qm = build(name);
        let path = dir.join(format!("{name}.fatm"));
        let etag = artifact::save(&qm, &path, isa).unwrap();
        let size = std::fs::metadata(&path).unwrap().len();
        println!(
            "bench_load: {name} -> {} ({size} bytes, {etag}, \
             packed for {})",
            path.display(),
            isa.name()
        );

        // Bit-exactness gate before timing anything: mmap-loaded logits
        // must equal the in-memory export's.
        let input = quant_input(&qm);
        let (loaded, rep) =
            artifact::load(&path, LoadOptions::default()).unwrap();
        let want = qm.run_quant(input.clone()).unwrap();
        let got = loaded.run_quant(input.clone()).unwrap();
        assert_eq!(want.data, got.data, "{name}: artifact logits diverge");
        println!(
            "bench_load: {name} verified bit-exact \
             (mapped={}, repacked={})",
            rep.mapped, rep.repacked
        );
        drop(loaded);

        let build_mean = bench(&format!("coldstart_build_{name}"), &opts, || {
            black_box(build(name));
        });
        let mmap_mean =
            bench(&format!("coldstart_mmap_load_{name}"), &opts, || {
                let (m, _) =
                    artifact::load(&path, LoadOptions::default()).unwrap();
                black_box(m);
            });
        let heap_mean =
            bench(&format!("coldstart_heap_load_{name}"), &opts, || {
                let (m, _) = artifact::load(
                    &path,
                    LoadOptions { force_heap: true, ..Default::default() },
                )
                .unwrap();
                black_box(m);
            });
        let first_mean =
            bench(&format!("coldstart_first_infer_{name}"), &opts, || {
                let (m, _) =
                    artifact::load(&path, LoadOptions::default()).unwrap();
                black_box(m.run_quant(input.clone()).unwrap());
            });
        report_speedup(
            &format!("artifact_mmap_vs_build_{name}"),
            build_mean,
            mmap_mean,
        );
        report_speedup(
            &format!("artifact_heap_vs_build_{name}"),
            build_mean,
            heap_mean,
        );

        // `ops` = int8 parameter bytes, so the gops column reads as
        // cold-start GB/s of model material made servable.
        let pb = qm.param_bytes;
        log.add("coldstart_build", name, 1, isa.name(), build_mean, pb);
        log.add("coldstart_mmap_load", name, 1, isa.name(), mmap_mean, pb);
        log.add("coldstart_heap_load", name, 1, isa.name(), heap_mean, pb);
        log.add("coldstart_first_infer", name, 1, isa.name(), first_mean, pb);

        let _ = std::fs::remove_file(&path);
    }

    let path = std::env::var("FAT_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_load.json".to_string());
    if let Err(e) = log.write(&path) {
        println!("BENCH log write failed ({path}): {e}");
    }
}
