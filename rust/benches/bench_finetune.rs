//! Native fine-tune benchmarks (EXPERIMENTS.md §Perf): step and
//! epoch-equivalent time of the analytic threshold trainer with a
//! worker-count sweep, plus the native FP32 evaluation throughput. No
//! artifacts needed — this is the `FAT_THREADS` scaling story of the
//! native backend.
//!
//!   cargo bench --bench bench_finetune
//!   FAT_BENCH_ITERS=20 cargo bench --bench bench_finetune

use fat::data::{loader, Split};
use fat::fp::{self, Trainer};
use fat::model::builtin;
use fat::quant::QuantMode;
use fat::util::bench::{bench_throughput, report_speedup, BenchOpts};
use fat::util::threads::fat_threads;

fn main() {
    let opts = BenchOpts::from_env();
    let batch = fp::train::TRAIN_BATCH;
    let (x, _) = loader::batch(Split::Train, &(0..batch as u64).collect::<Vec<_>>());

    let mut sweep = vec![1usize, 2, 4];
    if !sweep.contains(&fat_threads()) {
        sweep.push(fat_threads());
    }

    for model in ["tiny_cnn", "mnas_mini_10"] {
        let (g, sites, w) = builtin::load(model).unwrap();
        let prog = fp::FpProgram::compile(&g, &w, &sites, None).unwrap();
        let stats =
            fp::calibrate::calib_stats(&prog, 25, fat_threads()).unwrap();

        // native FP32 forward throughput (the teacher/eval path)
        for &t in &sweep {
            bench_throughput(
                &format!("fp_forward_{model}_b{batch}_t{t}"),
                &opts,
                batch,
                || {
                    std::hint::black_box(
                        prog.run_batch(&x, t).unwrap().len(),
                    );
                },
            );
        }

        // one fine-tune step (teacher + student + backward + grads)
        let mut base = 0.0;
        for &t in &sweep {
            let trainer =
                Trainer::new(&g, &w, &sites, &stats, QuantMode::SymScalar, t)
                    .unwrap();
            let tr = trainer.init_trainables();
            let mean = bench_throughput(
                &format!("finetune_step_{model}_b{batch}_t{t}"),
                &opts,
                batch,
                || {
                    let (loss, grads) =
                        trainer.loss_and_grads(&tr, &x).unwrap();
                    std::hint::black_box((loss, grads.len()));
                },
            );
            if t == 1 {
                base = mean;
            } else {
                report_speedup(
                    &format!("finetune_step_{model}_t{t}_vs_t1"),
                    base,
                    mean,
                );
            }
        }
        // paper-schedule framing: steps per epoch at stride 10
        let steps_per_epoch =
            fat::data::synth::TRAIN_SIZE / 10 / batch;
        println!(
            "BENCH finetune_epoch_{model} steps_per_epoch={steps_per_epoch} \
             (epoch time = steps x step mean above)"
        );
    }
}
