//! Serving-scheduler benchmarks (DESIGN.md §9–§10): micro-batched vs
//! unbatched `Int8Engine` throughput and latency percentiles under
//! concurrent closed-loop clients {1, 4, 16, 64}, on the builtin
//! `tiny_cnn` (artifact-free — runs on a bare checkout), in two
//! transports: **thread** (in-process engine clones) and **socket**
//! (HTTP over a live loopback server), so `BENCH_serve.json` carries
//! the cost of the network hop next to the scheduler numbers. Every
//! response is checked bit-exactly against the scalar/serial reference
//! interpreter `run_quant_ref`, so the speedups carry no accuracy
//! caveats. Measurements land in `BENCH_serve.json` (`FAT_BENCH_JSON`
//! overrides the path); raise `FAT_BENCH_ITERS` to lengthen the runs.

use std::sync::Arc;
use std::time::Duration;

use fat::int8::serve::{drive_clients, drive_with};
use fat::int8::{BatchOptions, Int8Engine, QTensor};
use fat::net::{HttpClient, ModelRegistry, Server, ServerOptions};
use fat::quant::session::{CalibOpts, QuantSession, QuantSpec};
use fat::util::bench::{percentiles, report_speedup, BenchLog, BenchOpts};

fn synth_image(per_img: usize, client: usize) -> Vec<u8> {
    (0..per_img)
        .map(|i| ((i * 31 + client * 97 + 13) % 256) as u8)
        .collect()
}

fn main() {
    let opts = BenchOpts::from_env();
    // Closed-loop requests per client, scaled by the shared iters knob.
    let per_client = (opts.iters * 8).clamp(8, 256);

    let rt = fat::runtime::Runtime::cpu().expect("cpu runtime");
    let reg = Arc::new(fat::runtime::Registry::new(Arc::new(rt)));
    let th = QuantSession::open(reg, fat::artifacts_dir(), "tiny_cnn")
        .unwrap()
        .calibrate(CalibOpts::images(16))
        .unwrap()
        .identity(&QuantSpec::default())
        .unwrap();
    let qm = th.export().unwrap();
    let sh = qm
        .graph
        .nodes
        .iter()
        .find(|n| n.op == fat::model::Op::Input)
        .and_then(|n| n.input_shape.clone())
        .expect("tiny_cnn has a shaped input");
    let per_img: usize = sh.iter().product();

    let batch_opts = BatchOptions::default();
    let unbatched =
        Int8Engine::new(qm.clone(), fat::int8::EngineOptions::default());
    let batched = Int8Engine::new(
        qm.clone(),
        fat::int8::EngineOptions::default().with_batch(batch_opts),
    );
    println!(
        "serve bench: tiny_cnn, {} worker(s), max_batch={} max_wait_us={}, \
         {per_client} requests/client",
        unbatched.threads(),
        batch_opts.max_batch,
        batch_opts.max_wait_us
    );

    let clients = [1usize, 4, 16, 64];
    let max_clients = *clients.iter().max().unwrap();
    let images: Vec<Vec<u8>> =
        (0..max_clients).map(|c| synth_image(per_img, c)).collect();
    let oracle: Vec<Vec<f32>> = images
        .iter()
        .map(|px| {
            let x: Vec<f32> =
                px.iter().map(|&p| p as f32 / 255.0).collect();
            let q = QTensor::quantize(
                vec![1, sh[0], sh[1], sh[2]],
                &x,
                qm.input_qp,
            );
            qm.run_quant_ref(q).unwrap().dequantize()
        })
        .collect();

    // One loopback server carries the socket columns: both engines,
    // routed by model name, behind generous admission limits so the
    // bench measures the hop, not load shedding.
    let registry = ModelRegistry::new();
    registry.insert("unbatched", unbatched.clone());
    registry.insert("batched", batched.clone());
    let server_opts = ServerOptions {
        max_conns: 2 * max_clients,
        max_inflight: 2 * max_clients,
        ..ServerOptions::default()
    };
    let server = Server::bind("127.0.0.1:0", registry, server_opts)
        .expect("loopback bind");
    let addr = server.local_addr();

    let mut log = BenchLog::default();
    for c in clients {
        let stats0 = batched.batcher_stats().unwrap_or((0, 0, 0));
        let mut secs_per_req = [0.0f64; 2];
        let mut socket_secs = [0.0f64; 2];
        for (mode_i, (name, engine)) in
            [("unbatched", &unbatched), ("batched", &batched)]
                .into_iter()
                .enumerate()
        {
            let rep = drive_clients(
                engine,
                c,
                per_client,
                |i| images[i].clone(),
                |i| Some(oracle[i].clone()),
            )
            .expect("bit-exact serving");
            let mut lat = rep.latencies_secs.clone();
            let p = percentiles(&mut lat);
            let rps = rep.requests as f64 / rep.wall_secs.max(1e-12);
            println!(
                "BENCH serve_{name}_c{c} rps={rps:.1} p50_ms={:.3} \
                 p95_ms={:.3} p99_ms={:.3} requests={}",
                p.p50 * 1e3,
                p.p95 * 1e3,
                p.p99 * 1e3,
                rep.requests
            );
            log.add_latency(
                "serve_tiny_cnn",
                name,
                c,
                engine.threads(),
                rep.requests,
                rep.wall_secs,
                p,
            );
            secs_per_req[mode_i] = rep.wall_secs / rep.requests as f64;
        }
        for (mode_i, name) in
            ["unbatched", "batched"].into_iter().enumerate()
        {
            let rep = drive_with(
                |_| HttpClient::connect(addr, name),
                c,
                per_client,
                |i| images[i].clone(),
                |i| Some(oracle[i].clone()),
            )
            .expect("bit-exact loopback serving");
            let mut lat = rep.latencies_secs.clone();
            let p = percentiles(&mut lat);
            let rps = rep.requests as f64 / rep.wall_secs.max(1e-12);
            println!(
                "BENCH serve_socket_{name}_c{c} rps={rps:.1} p50_ms={:.3} \
                 p95_ms={:.3} p99_ms={:.3} requests={}",
                p.p50 * 1e3,
                p.p95 * 1e3,
                p.p99 * 1e3,
                rep.requests
            );
            log.add_latency(
                "serve_socket_tiny_cnn",
                name,
                c,
                batched.threads(),
                rep.requests,
                rep.wall_secs,
                p,
            );
            socket_secs[mode_i] = rep.wall_secs / rep.requests as f64;
        }
        report_speedup(
            &format!("serve_batched_vs_unbatched_c{c}"),
            secs_per_req[0],
            secs_per_req[1],
        );
        report_speedup(
            &format!("serve_loopback_vs_inprocess_c{c}"),
            socket_secs[1],
            secs_per_req[1],
        );
        // stats delta = this client count's batched runs (both transports)
        if let Some((req, bat, rows)) = batched.batcher_stats() {
            let (dreq, dbat, drows) =
                (req - stats0.0, bat - stats0.1, rows - stats0.2);
            println!(
                "batcher c{c}: {dreq} requests -> {dbat} batches (mean \
                 occupancy {:.2})",
                drows as f64 / dbat.max(1) as f64
            );
        }
    }

    server.drain(Duration::from_secs(5));
    let st = server.stats();
    println!(
        "server: {} conns accepted, {} admitted, {} rejected",
        st.accepted_conns, st.admitted, st.rejected
    );

    let path = std::env::var("FAT_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    if let Err(e) = log.write(&path) {
        println!("BENCH log write failed ({path}): {e}");
    }
}
