//! Table-1 pipeline bench: times each stage of the scalar-mode experiment
//! (calibration, no-finetune eval, one fine-tune step, final eval) per
//! architecture. The accuracy regeneration itself is the `table1` binary;
//! this harness tracks the *cost* of producing the table.

use std::sync::Arc;

use fat::coordinator::experiments::{Ctx, TABLE_MODELS};
use fat::coordinator::PipelineConfig;
use fat::quant::export::QuantMode;
use fat::quant::session::{CalibOpts, QuantSpec};
use fat::runtime::{Registry, Runtime};
use fat::util::bench::{bench, BenchOpts};

fn main() {
    let artifacts = fat::artifacts_dir();
    if !artifacts.join("models/mobilenet_v2_mini").exists() {
        println!("SKIP table1 bench (run `make artifacts`)");
        return;
    }
    let ctx = Ctx::new(
        Arc::new(Registry::new(Arc::new(Runtime::cpu().unwrap()))),
        &artifacts,
    );
    let opts = BenchOpts { warmup: 0, iters: 3, max_secs: 120.0 };
    let spec = QuantSpec::from_mode(QuantMode::SymScalar);
    for model in TABLE_MODELS {
        let session = ctx.session(model).unwrap();
        bench(&format!("t1_calibrate_100_{model}"), &opts, || {
            std::hint::black_box(
                session
                    .calibrate(CalibOpts::images(100))
                    .unwrap()
                    .stats()
                    .batches,
            );
        });
        let cal = session.calibrate(CalibOpts::images(100)).unwrap();
        let th = cal.identity(&spec).unwrap();
        bench(&format!("t1_eval_500_{model}"), &opts, || {
            std::hint::black_box(th.quant_accuracy(500).unwrap());
        });
        let mut cfg = PipelineConfig::default();
        cfg.max_steps = 1;
        cfg.epochs = 1;
        let fopts = cfg.finetune_opts(false);
        bench(&format!("t1_finetune_step_{model}"), &opts, || {
            std::hint::black_box(
                cal.finetune(&spec, &fopts, |_, _, _| {})
                    .unwrap()
                    .losses()
                    .len(),
            );
        });
    }
}
