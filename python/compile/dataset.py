"""SynthShapes: deterministic procedural image-classification dataset.

Stands in for ImageNet-2012 in the FAT reproduction (see DESIGN.md §2).
10 classes of procedural 32x32x3 images: per-sample background gradient,
one class-determined foreground pattern, per-pixel noise, and sparse x3
"outlier" pixels that induce the activation/weight outliers the paper's
threshold-training targets (paper Fig. 1).

Bit-exactly mirrored by ``rust/src/data/synth.rs``: identical hash keys,
identical f32 formula order, no transcendental functions (only + - * /,
floor, abs, min/max, comparisons — all IEEE-exact).

Dataset regions (by seed): train=0x5EED_0001, val=0x5EED_0002. The paper's
"~10% of ImageNet" becomes a 10% index-stride subset of train; its "100
calibration images" are train indices 0..100.
"""

from __future__ import annotations

import numpy as np

from . import prng

IMG = 32
CHANNELS = 3
NUM_CLASSES = 10

SEED_TRAIN = 0x5EED0001
SEED_VAL = 0x5EED0002

TRAIN_SIZE = 12000
VAL_SIZE = 2000
CALIB_SIZE = 100
FINETUNE_FRACTION = 10  # every 10th train image => the paper's "~10%"

# Parameter slots (must match rust/src/data/synth.rs)
S_BG = 0  # 9 consecutive slots: background plane coefficients
S_CX, S_CY, S_R = 9, 10, 11
S_FG = 12  # 3 consecutive slots: foreground colour
S_FREQ = 15
S_EDGE = 16


def _params(seed: int, idx: np.ndarray):
    """Draw all scalar per-sample parameters. idx: (B,) u64."""
    bg = np.stack(
        [prng.uniform(seed, idx, S_BG + k) for k in range(9)], axis=-1
    )  # (B, 9)
    cx = prng.uniform_range(0.30, 0.70, seed, idx, S_CX)
    cy = prng.uniform_range(0.30, 0.70, seed, idx, S_CY)
    r = prng.uniform_range(0.12, 0.30, seed, idx, S_R)
    fg = np.stack(
        [
            prng.uniform_range(0.35, 1.0, seed, idx, S_FG + k)
            for k in range(CHANNELS)
        ],
        axis=-1,
    )  # (B, 3)
    freq = np.float32(3.0) + np.floor(
        prng.uniform(seed, idx, S_FREQ) * np.float32(3.0)
    )  # 3, 4 or 5
    edge = prng.uniform_range(0.55, 0.95, seed, idx, S_EDGE)
    return bg, cx, cy, r, fg, freq, edge


def _frac(x: np.ndarray) -> np.ndarray:
    return x - np.floor(x)


def _mask(label, u, v, cx, cy, r, freq, edge):
    """Class-conditional foreground mask. All inputs f32, broadcast (B,H,W)."""
    du = u - cx
    dv = v - cy
    adu = np.abs(du)
    adv = np.abs(dv)
    d2 = du * du + dv * dv
    r2 = r * r
    half = np.float32(0.5)

    box = np.maximum(adu, adv) < r * np.float32(1.1)
    m0 = d2 < r2  # circle
    m1 = np.maximum(adu, adv) < r * np.float32(0.9)  # square
    m2 = (adu + adv) < r * np.float32(1.2)  # diamond
    m3 = (d2 < r2) & (d2 > r2 * np.float32(0.3))  # ring
    m4 = ((adu < r * np.float32(0.32)) | (adv < r * np.float32(0.32))) & (
        np.maximum(adu, adv) < r
    )  # cross
    m5 = (_frac(v * freq) < half) & box  # h-stripes
    m6 = (_frac(u * freq) < half) & box  # v-stripes
    m7 = (_frac((np.floor(u * freq) + np.floor(v * freq)) * half) < np.float32(0.25)) & box  # checker
    gx = _frac(u * freq) - half
    gy = _frac(v * freq) - half
    m8 = ((gx * gx + gy * gy) < np.float32(0.06)) & box  # dot grid
    m9 = (
        (dv > -r)
        & (dv < r)
        & (adu < (dv + r) * edge * half)
    )  # triangle (widening downward)

    masks = [m0, m1, m2, m3, m4, m5, m6, m7, m8, m9]
    out = np.zeros_like(m0)
    for k in range(NUM_CLASSES):
        out = np.where(label == k, masks[k], out)
    return out


def generate(seed: int, indices: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Render images for `indices` (u64 array). Returns (B,H,W,C) f32, (B,) i32.

    Labels are `index % 10` (balanced classes under any contiguous range).
    """
    idx = np.asarray(indices, dtype=np.uint64)
    B = idx.shape[0]
    labels = (idx % np.uint64(NUM_CLASSES)).astype(np.int32)

    bg, cx, cy, r, fg, freq, edge = _params(seed, idx)

    xs = np.arange(IMG, dtype=np.uint64)
    ys = np.arange(IMG, dtype=np.uint64)
    # pixel centre coordinates, f32-exact: (k + 0.5) * (1/32)
    u = (xs.astype(np.float32) + np.float32(0.5)) * np.float32(1.0 / IMG)
    v = (ys.astype(np.float32) + np.float32(0.5)) * np.float32(1.0 / IMG)
    u = u[None, None, :]  # (1,1,W)
    v = v[None, :, None]  # (1,H,1)

    def bc(a):  # (B,) -> (B,1,1)
        return a[:, None, None]

    lab_b = bc(labels)
    mask = _mask(
        lab_b, u, v, bc(cx), bc(cy), bc(r), bc(freq), bc(edge)
    )  # (B,H,W)

    img = np.empty((B, IMG, IMG, CHANNELS), dtype=np.float32)
    for ch in range(CHANNELS):
        a = bc(bg[:, 3 * ch + 0])
        b = bc(bg[:, 3 * ch + 1])
        c = bc(bg[:, 3 * ch + 2])
        base = np.float32(0.15) + np.float32(0.5) * (a * u + b * v + c * (u * v))
        f = bc(fg[:, ch])
        pix = np.where(mask, f, base)
        img[..., ch] = pix

    # Per-pixel noise + sparse outliers (slots keyed by pixel coordinate).
    xg = xs[None, None, :, None]
    yg = ys[None, :, None, None]
    cg = np.arange(CHANNELS, dtype=np.uint64)[None, None, None, :]
    ib = idx[:, None, None, None]
    noise = prng.uniform(seed, ib, prng.SLOT_NOISE, xg, yg, cg)
    img += (noise - np.float32(0.5)) * np.float32(0.12)

    out_draw = prng.uniform(seed, ib, prng.SLOT_OUTLIER, xg, yg, np.uint64(0))
    outlier = out_draw < np.float32(1.0 / 96.0)
    img = np.where(outlier, img * np.float32(3.0), img)
    img = np.minimum(np.maximum(img, np.float32(0.0)), np.float32(3.0))
    return img, labels


def train_batch(indices) -> tuple[np.ndarray, np.ndarray]:
    return generate(SEED_TRAIN, np.asarray(indices, dtype=np.uint64))


def val_batch(indices) -> tuple[np.ndarray, np.ndarray]:
    return generate(SEED_VAL, np.asarray(indices, dtype=np.uint64))


def calib_indices() -> np.ndarray:
    """The paper's '100 images from the training set used as calibration'."""
    return np.arange(CALIB_SIZE, dtype=np.uint64)


def finetune_indices() -> np.ndarray:
    """~10% unlabeled subset of train (paper §4.1.2)."""
    return np.arange(0, TRAIN_SIZE, FINETUNE_FRACTION, dtype=np.uint64)
