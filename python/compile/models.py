"""Model zoo: mini mobile architectures for the FAT reproduction.

DESIGN.md §2 maps these to the paper's nets:

  * ``mobilenet_v2_mini`` — inverted residual bottlenecks, ReLU6, DWS layers
    (the net whose *scalar* quantization collapses in the paper's Table 1).
  * ``mnas_mini_10`` / ``mnas_mini_13`` — MBConv-style blocks at width
    multipliers 1.0 / 1.3 with ReLU (paper's MNas-1.0 / MNas-1.3).
  * ``resnet_mini`` — plain residual net used for the Fig. 1-2 weight
    histograms.
"""

from __future__ import annotations

from .graph import Builder, GraphDef


def _inverted_residual(b: Builder, x, cin, cout, stride, t, act, hint):
    mid = cin * t
    y = b.conv(x, cin, mid, k=1, stride=1, act=act, hint=f"{hint}_exp")
    y = b.dwconv(y, mid, k=3, stride=stride, act=act, hint=f"{hint}_dw")
    y = b.conv(y, mid, cout, k=1, stride=1, act=None, hint=f"{hint}_proj")
    if stride == 1 and cin == cout:
        y = b.add(x, y, hint=f"{hint}_res")
    return y


def mobilenet_v2_mini() -> GraphDef:
    b = Builder("mobilenet_v2_mini")
    x = "input"
    x = b.conv(x, 3, 16, k=3, stride=1, act="relu6", hint="stem")
    cfg = [  # (t, cout, stride)
        (1, 16, 1),
        (4, 24, 2),
        (4, 24, 1),
        (4, 32, 2),
        (4, 32, 1),
        (4, 64, 2),
        (4, 64, 1),
    ]
    cin = 16
    for i, (t, cout, s) in enumerate(cfg):
        x = _inverted_residual(b, x, cin, cout, s, t, "relu6", f"b{i}")
        cin = cout
    x = b.conv(x, cin, 128, k=1, stride=1, act="relu6", hint="headconv")
    x = b.head(x, 128)
    return b.build()


def _mnas(width: float, name: str) -> GraphDef:
    def c(ch):
        return max(8, int(ch * width + 0.5))

    b = Builder(name)
    x = "input"
    x = b.conv(x, 3, c(16), k=3, stride=1, act="relu", hint="stem")
    # SepConv block (dw3x3 + pw linear), as in MNasNet's first block
    x = b.dwconv(x, c(16), k=3, stride=1, act="relu", hint="sep_dw")
    x = b.conv(x, c(16), c(16), k=1, stride=1, act=None, hint="sep_pw")
    cfg = [  # (t, cout, stride, n)
        (3, 24, 2, 2),
        (3, 40, 2, 2),
        (6, 64, 2, 2),
    ]
    cin = c(16)
    for bi, (t, cout, s, n) in enumerate(cfg):
        for j in range(n):
            x = _inverted_residual(
                b, x, cin, c(cout), s if j == 0 else 1, t, "relu", f"m{bi}_{j}"
            )
            cin = c(cout)
    x = b.conv(x, cin, c(128), k=1, stride=1, act="relu", hint="headconv")
    x = b.head(x, c(128))
    return b.build()


def mnas_mini_10() -> GraphDef:
    return _mnas(1.0, "mnas_mini_10")


def mnas_mini_13() -> GraphDef:
    return _mnas(1.3, "mnas_mini_13")


def resnet_mini() -> GraphDef:
    b = Builder("resnet_mini")
    x = "input"
    x = b.conv(x, 3, 16, k=3, stride=1, act="relu", hint="stem")
    cin = 16
    for si, (cout, s) in enumerate([(16, 1), (32, 2), (64, 2)]):
        for j in range(2):
            stride = s if j == 0 else 1
            y = b.conv(
                x, cin, cout, k=3, stride=stride, act="relu", hint=f"r{si}_{j}a"
            )
            y = b.conv(y, cout, cout, k=3, stride=1, act=None, hint=f"r{si}_{j}b")
            if stride == 1 and cin == cout:
                y = b.add(x, y, hint=f"r{si}_{j}")
            else:
                sc = b.conv(
                    x, cin, cout, k=1, stride=stride, act=None, hint=f"r{si}_{j}s"
                )
                y = b.add(sc, y, hint=f"r{si}_{j}")
            x = b.add_node("relu", [y], hint=f"r{si}_{j}o")
            cin = cout
    x = b.head(x, 64)
    return b.build()


ZOO = {
    "mobilenet_v2_mini": mobilenet_v2_mini,
    "mnas_mini_10": mnas_mini_10,
    "mnas_mini_13": mnas_mini_13,
    "resnet_mini": resnet_mini,
}
