"""JAX NN primitives used by the graph interpreter (NHWC / HWIO)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .graph import EPS

DN = ("NHWC", "HWIO", "NHWC")


def conv2d(x, w, stride: int):
    return lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=DN
    )


def dwconv2d(x, w, stride: int):
    # w: (k, k, C) -> HWIO (k, k, 1, C) with feature_group_count = C
    c = w.shape[-1]
    return lax.conv_general_dilated(
        x,
        w[:, :, None, :],
        (stride, stride),
        "SAME",
        dimension_numbers=DN,
        feature_group_count=c,
    )


def dense(x, w):
    return jnp.dot(x, w)


def bn_infer(x, gamma, beta, mean, var):
    inv = gamma * lax.rsqrt(var + EPS)
    return x * inv + (beta - mean * inv)


def bn_train(x, gamma, beta):
    """Batch-stats BN for pretraining. Returns (y, batch_mean, batch_var)."""
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axis=axes)
    var = jnp.var(x, axis=axes)
    inv = gamma * lax.rsqrt(var + EPS)
    return x * inv + (beta - mean * inv), mean, var


def relu(x):
    return jnp.maximum(x, 0.0)


def relu6(x):
    return jnp.clip(x, 0.0, 6.0)


def gap(x):
    return jnp.mean(x, axis=(1, 2))


def softmax_xent(logits, labels, num_classes):
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))
