"""Graph IR: the single source of truth for model topology.

A ``GraphDef`` is a topologically-ordered list of SSA nodes. The same IR is

  * interpreted forward in JAX (``interp.py``) for pretraining, fake-quant
    fine-tuning and AOT lowering, and
  * exported as ``graph.json`` and interpreted by the Rust int8 engine
    (``rust/src/int8/``) and the Rust quant substrate (BN fold, DWS rescale).

Ops: input, conv (k,s, same-pad), dwconv (k,s, depth multiplier 1), dense,
bn, relu, relu6, add, gap (global average pool). Layout is NHWC; conv
weights HWIO; dwconv weights HWC (I=1 implied); dense weights IO.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

EPS = 1e-3  # BN epsilon (paper eq. 10-11); shared with Rust


@dataclass
class Node:
    id: str
    op: str
    inputs: list
    attrs: dict = field(default_factory=dict)


@dataclass
class GraphDef:
    name: str
    nodes: list  # topo order; nodes[0].op == 'input'
    num_classes: int = 10

    def node(self, nid: str) -> Node:
        return next(n for n in self.nodes if n.id == nid)

    def conv_like(self) -> list:
        return [n for n in self.nodes if n.op in ("conv", "dwconv", "dense")]

    def to_json(self) -> str:
        return json.dumps(
            {
                "name": self.name,
                "num_classes": self.num_classes,
                "nodes": [
                    {"id": n.id, "op": n.op, "inputs": n.inputs, **n.attrs}
                    for n in self.nodes
                ],
            },
            indent=1,
        )


class Builder:
    """Small fluent helper for writing model definitions."""

    def __init__(self, name: str):
        self.nodes = [Node("input", "input", [], {"shape": [32, 32, 3]})]
        self.name = name
        self._ctr = {}

    def _nid(self, op: str, hint: str) -> str:
        if hint is None:
            k = self._ctr.get(op, 0)
            self._ctr[op] = k + 1
            return f"{op}{k}"
        key = (hint, op)
        k = self._ctr.get(key, 0)
        self._ctr[key] = k + 1
        return f"{hint}_{op}" if k == 0 else f"{hint}_{op}{k}"

    def add_node(self, op, inputs, hint=None, **attrs) -> str:
        nid = self._nid(op, hint)
        self.nodes.append(Node(nid, op, list(inputs), attrs))
        return nid

    def conv(self, x, cin, cout, k=3, stride=1, bn=True, act="relu6", hint=None):
        h = hint or f"c{len(self.nodes)}"
        x = self.add_node(
            "conv", [x], hint=h, k=k, stride=stride, cin=cin, cout=cout
        )
        if bn:
            x = self.add_node("bn", [x], hint=h, ch=cout)
        if act:
            x = self.add_node(act, [x], hint=h)
        return x

    def dwconv(self, x, ch, k=3, stride=1, bn=True, act="relu6", hint=None):
        h = hint or f"d{len(self.nodes)}"
        x = self.add_node("dwconv", [x], hint=h, k=k, stride=stride, ch=ch)
        if bn:
            x = self.add_node("bn", [x], hint=h, ch=ch)
        if act:
            x = self.add_node(act, [x], hint=h)
        return x

    def add(self, a, b, hint=None):
        return self.add_node("add", [a, b], hint=hint or f"a{len(self.nodes)}")

    def head(self, x, cin, num_classes=10):
        x = self.add_node("gap", [x], hint="head")
        x = self.add_node(
            "dense", [x], hint="head", cin=cin, cout=num_classes
        )
        return x

    def build(self, num_classes=10) -> GraphDef:
        return GraphDef(self.name, self.nodes, num_classes)


# ---------------------------------------------------------------------------
# Parameter initialisation (build-time only; numpy RandomState, not portable)
# ---------------------------------------------------------------------------

def weight_names(n: Node) -> list:
    if n.op in ("conv", "dwconv", "dense"):
        names = [f"{n.id}.w"]
        if n.attrs.get("bias", False):
            names.append(f"{n.id}.b")
        return names
    if n.op == "bn":
        return [f"{n.id}.gamma", f"{n.id}.beta", f"{n.id}.mean", f"{n.id}.var"]
    return []


def weight_shape(n: Node, name: str):
    a = n.attrs
    if n.op == "conv":
        return (a["k"], a["k"], a["cin"], a["cout"]) if name.endswith(".w") else (a["cout"],)
    if n.op == "dwconv":
        return (a["k"], a["k"], a["ch"]) if name.endswith(".w") else (a["ch"],)
    if n.op == "dense":
        return (a["cin"], a["cout"]) if name.endswith(".w") else (a["cout"],)
    if n.op == "bn":
        return (a["ch"],)
    raise ValueError(n.op)


def init_params(g: GraphDef, seed: int = 0) -> dict:
    rs = np.random.RandomState(seed)
    p = {}
    for n in g.nodes:
        for name in weight_names(n):
            shape = weight_shape(n, name)
            if name.endswith(".w"):
                fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
                if n.op == "dwconv":
                    fan_in = n.attrs["k"] ** 2
                std = np.sqrt(2.0 / fan_in)
                p[name] = rs.normal(0, std, shape).astype(np.float32)
            elif name.endswith((".b", ".beta", ".mean")):
                p[name] = np.zeros(shape, np.float32)
            else:  # gamma, var
                p[name] = np.ones(shape, np.float32)
    return p


# ---------------------------------------------------------------------------
# Batch-norm folding (paper eq. 10-11). Mirrored by rust/src/quant/fold.rs.
# ---------------------------------------------------------------------------

def fold_bn(g: GraphDef, params: dict):
    """Return (folded_graph, folded_params).

    Every conv/dwconv followed by a bn absorbs it: W' = gamma*W/sqrt(var+eps),
    b' = beta - gamma*mean/sqrt(var+eps). Folded conv nodes gain bias=True;
    bn nodes are removed and their consumers re-wired.
    """
    followers = {}
    for n in g.nodes:
        if n.op == "bn":
            followers[n.inputs[0]] = n
    rewrite = {}
    new_nodes, new_params = [], {}
    for n in g.nodes:
        if n.op == "bn":
            src = g.node(n.inputs[0])
            if src.op in ("conv", "dwconv"):
                rewrite[n.id] = rewrite.get(src.id, src.id)
                continue  # folded away
            raise ValueError(f"bn after {src.op} unsupported")
        inputs = [rewrite.get(i, i) for i in n.inputs]
        attrs = dict(n.attrs)
        if n.op in ("conv", "dwconv") and n.id in followers:
            bn = followers[n.id]
            gamma = params[f"{bn.id}.gamma"]
            beta = params[f"{bn.id}.beta"]
            mean = params[f"{bn.id}.mean"]
            var = params[f"{bn.id}.var"]
            scale = gamma / np.sqrt(var + np.float32(EPS))
            w = params[f"{n.id}.w"]
            neww = w * scale  # broadcast over last (output-channel) axis
            newb = beta - gamma * mean / np.sqrt(var + np.float32(EPS))
            attrs["bias"] = True
            new_params[f"{n.id}.w"] = neww.astype(np.float32)
            new_params[f"{n.id}.b"] = newb.astype(np.float32)
        elif n.op in ("conv", "dwconv", "dense"):
            attrs["bias"] = True
            new_params[f"{n.id}.w"] = params[f"{n.id}.w"]
            new_params[f"{n.id}.b"] = params.get(
                f"{n.id}.b", np.zeros(weight_shape(n, f"{n.id}.b"), np.float32)
            )
        new_nodes.append(Node(n.id, n.op, inputs, attrs))
    return GraphDef(g.name, new_nodes, g.num_classes), new_params


def folded_weight_order(g: GraphDef) -> list:
    """Canonical (name, ...) order for marshalling folded weights to HLO."""
    out = []
    for n in g.nodes:
        if n.op in ("conv", "dwconv", "dense"):
            out.append(f"{n.id}.w")
            out.append(f"{n.id}.b")
    return out
