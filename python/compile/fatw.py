"""FATW: tiny named-tensor container shared with Rust (rust/src/model/fatw.rs).

Layout (little-endian):
  magic  8 bytes  b"FATW0001"
  count  u32
  per tensor:
    name_len u32, name bytes (utf-8)
    dtype    u8   (0=f32, 1=i8, 2=i32, 3=u8)
    ndim     u8
    dims     u32 * ndim
    data     raw bytes (row-major)
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"FATW0001"
_DTYPES = {np.dtype("float32"): 0, np.dtype("int8"): 1, np.dtype("int32"): 2, np.dtype("uint8"): 3}
_RDTYPES = {v: k for k, v in _DTYPES.items()}


def write(path: str, tensors: dict):
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read(path: str) -> dict:
    out = {}
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, "bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode()
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = _RDTYPES[dt]
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(
                f.read(n * dtype.itemsize), dtype=dtype
            ).reshape(dims)
            out[name] = data
    return out
