"""Forward interpreter over the Graph IR, with quantization hooks.

One interpreter serves all paths:
  * FP inference / the distillation teacher (no hooks),
  * pretraining (bn_mode="train": batch-stats BN, returns new running stats),
  * the fake-quantized student (weight_hook + act_hook from quantize.py),
  * calibration statistics (capture dict).

Activation-quantization *sites* follow standard int8 placement (Jacob et
al., mirrored by the Rust int8 engine): a node output is a site unless it
is consumed solely by an immediately-following bn/relu/relu6 (the engine
fuses conv→requant→clamp, so no tensor is materialised between them).
"""

from __future__ import annotations

import jax.numpy as jnp

from . import nn
from .graph import GraphDef


def consumers(g: GraphDef) -> dict:
    out = {n.id: [] for n in g.nodes}
    for n in g.nodes:
        for i in n.inputs:
            out[i].append(n)
    return out


def enumerate_sites(g: GraphDef) -> list:
    """Activation quant sites of a *folded* graph: [(node_id, unsigned)]."""
    cons = consumers(g)
    sites = []
    for n in g.nodes:
        cs = cons[n.id]
        if len(cs) == 1 and cs[0].op in ("bn", "relu", "relu6"):
            continue  # fused into the consumer's requant clamp
        if n.op == "bn":
            continue
        unsigned = n.op in ("relu", "relu6", "input") or (
            n.op == "gap" and _unsigned_src(g, n)
        )
        sites.append((n.id, bool(unsigned)))
    return sites


def _unsigned_src(g: GraphDef, n) -> bool:
    src = g.node(n.inputs[0])
    return src.op in ("relu", "relu6", "input")


def channel_stat_nodes(g: GraphDef) -> list:
    """Conv-like nodes whose per-channel pre-activation ranges are captured
    during calibration (needed by §3.3 DWS rescaling and vector-quant
    diagnostics): [(node_id, channels)]."""
    out = []
    for n in g.nodes:
        if n.op in ("conv", "dwconv"):
            ch = n.attrs.get("cout", n.attrs.get("ch"))
            out.append((n.id, int(ch)))
    return out


def forward(
    g: GraphDef,
    params: dict,
    x,
    *,
    bn_mode: str = "infer",
    weight_hook=None,
    act_hook=None,
    capture: dict | None = None,
):
    """Interpret the graph. Returns logits (and bn stats dict in train mode).

    weight_hook(node, w) -> w' fake-quantizes conv/dwconv/dense weights.
    act_hook(node_id, t) -> t' fake-quantizes site tensors (only called on
    sites as defined by enumerate_sites).
    capture, if given, records per-node statistics for calibration.
    """
    site_ids = {s for s, _ in enumerate_sites(g)} if act_hook else set()
    bn_stats = {}

    def site(nid, t):
        if capture is not None:
            _capture(capture, g, nid, t)
        if nid in site_ids:
            t = act_hook(nid, t)
        return t

    vals = {}
    for n in g.nodes:
        if n.op == "input":
            vals[n.id] = site(n.id, x)
            continue
        a = vals[n.inputs[0]]
        if n.op == "conv" or n.op == "dwconv":
            w = params[f"{n.id}.w"]
            if weight_hook is not None:
                w = weight_hook(n, w)
            y = (
                nn.conv2d(a, w, n.attrs["stride"])
                if n.op == "conv"
                else nn.dwconv2d(a, w, n.attrs["stride"])
            )
            if n.attrs.get("bias"):
                y = y + params[f"{n.id}.b"]
        elif n.op == "dense":
            w = params[f"{n.id}.w"]
            if weight_hook is not None:
                w = weight_hook(n, w)
            y = nn.dense(a, w)
            if n.attrs.get("bias"):
                y = y + params[f"{n.id}.b"]
        elif n.op == "bn":
            if bn_mode == "train":
                y, m, v = nn.bn_train(
                    a, params[f"{n.id}.gamma"], params[f"{n.id}.beta"]
                )
                bn_stats[n.id] = (m, v)
            else:
                y = nn.bn_infer(
                    a,
                    params[f"{n.id}.gamma"],
                    params[f"{n.id}.beta"],
                    params[f"{n.id}.mean"],
                    params[f"{n.id}.var"],
                )
        elif n.op == "relu":
            y = nn.relu(a)
        elif n.op == "relu6":
            y = nn.relu6(a)
        elif n.op == "add":
            y = a + vals[n.inputs[1]]
        elif n.op == "gap":
            y = nn.gap(a)
        else:
            raise ValueError(f"unknown op {n.op}")
        vals[n.id] = site(n.id, y)

    logits = vals[g.nodes[-1].id]
    if bn_mode == "train":
        return logits, bn_stats
    return logits


def _capture(capture: dict, g: GraphDef, nid: str, t):
    node = g.node(nid)
    entry = {}
    entry["min"] = jnp.min(t)
    entry["max"] = jnp.max(t)
    if node.op in ("conv", "dwconv") and t.ndim == 4:
        entry["ch_min"] = jnp.min(t, axis=(0, 1, 2))
        entry["ch_max"] = jnp.max(t, axis=(0, 1, 2))
    capture[nid] = entry
