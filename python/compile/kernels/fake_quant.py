"""L1 Pallas fake-quantization kernels (forward paths).

These are the hot-spot ops of the FAT training graph: every weight tensor
and every activation site runs a quantize→clip→dequantize per step. The
kernels run under ``interpret=True`` (CPU PJRT); on TPU the same BlockSpecs
tile (rows, lanes) VMEM blocks — see DESIGN.md §Hardware-Adaptation.

Gradient (STE) wrappers live in ``quantize.py``; pure-jnp oracles in
``ref.py``; pytest/hypothesis compare the two.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row tile for the gridded kernels. 256 rows x C lanes keeps each VMEM
# block ≤ 128 KiB for C ≤ 128 at f32.
ROWS = 256


def _sym_kernel(x_ref, t_ref, o_ref, *, qmax, qmin):
    t = t_ref[0, 0]
    s = qmax / t
    y = jnp.clip(jnp.round(x_ref[...] * s), qmin, qmax) / s
    o_ref[...] = y


def _sym_ch_kernel(x_ref, t_ref, o_ref, *, qmax, qmin):
    s = qmax / t_ref[0, :]  # (C,) broadcast along rows
    y = jnp.clip(jnp.round(x_ref[...] * s), qmin, qmax) / s
    o_ref[...] = y


def _asym_kernel(x_ref, l_ref, w_ref, o_ref, *, qspan):
    left = l_ref[0, 0]
    width = w_ref[0, 0]
    s = qspan / width
    y = jnp.clip(jnp.round((x_ref[...] - left) * s), 0.0, qspan) / s + left
    o_ref[...] = y


def _rows2d(x):
    """Collapse x to (rows, lastdim) for tiling; remember original shape."""
    shape = x.shape
    if x.ndim == 1:
        return x.reshape(1, -1), shape
    return x.reshape(-1, shape[-1]), shape


@functools.partial(jax.jit, static_argnames=("unsigned",))
def fq_sym(x, t, unsigned=False):
    """Symmetric per-tensor fake-quant. t: scalar threshold (>0)."""
    qmax = 255.0 if unsigned else 127.0
    qmin = 0.0 if unsigned else -127.0
    x2, shape = _rows2d(x)
    n = x2.shape[0]
    grid = (pl.cdiv(n, ROWS),)
    y = pl.pallas_call(
        functools.partial(_sym_kernel, qmax=qmax, qmin=qmin),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, x2.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, x2.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x2.dtype),
        interpret=True,
    )(x2, t.reshape(1, 1).astype(x.dtype))
    return y.reshape(shape)


@jax.jit
def fq_sym_ch(x, t):
    """Symmetric per-channel (last axis) fake-quant. t: (C,) thresholds."""
    x2, shape = _rows2d(x)
    n, c = x2.shape
    grid = (pl.cdiv(n, ROWS),)
    y = pl.pallas_call(
        functools.partial(_sym_ch_kernel, qmax=127.0, qmin=-127.0),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, c), lambda i: (i, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x2.dtype),
        interpret=True,
    )(x2, t.reshape(1, -1).astype(x.dtype))
    return y.reshape(shape)


@jax.jit
def fq_asym(x, left, width):
    """Affine uint8 fake-quant over [left, left+width]."""
    x2, shape = _rows2d(x)
    n = x2.shape[0]
    grid = (pl.cdiv(n, ROWS),)
    y = pl.pallas_call(
        functools.partial(_asym_kernel, qspan=255.0),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, x2.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, x2.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x2.dtype),
        interpret=True,
    )(
        x2,
        left.reshape(1, 1).astype(x.dtype),
        width.reshape(1, 1).astype(x.dtype),
    )
    return y.reshape(shape)
