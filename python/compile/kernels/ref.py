"""Pure-jnp oracles for every Pallas kernel (the correctness standard).

pytest + hypothesis assert kernels == oracles across shape/param sweeps.
The Rust quant module and int8 engine are additionally tested against
goldens produced by these oracles at artifact-build time.
"""

from __future__ import annotations

import jax.numpy as jnp


def fq_sym(x, t, unsigned=False):
    qmax = 255.0 if unsigned else 127.0
    qmin = 0.0 if unsigned else -127.0
    s = qmax / t
    return jnp.clip(jnp.round(x * s), qmin, qmax) / s


def fq_sym_ch(x, t):
    """Per-channel symmetric fake-quant over the last axis. t: (C,)."""
    s = 127.0 / t
    return jnp.clip(jnp.round(x * s), -127.0, 127.0) / s


def fq_asym(x, left, width):
    s = 255.0 / width
    return jnp.clip(jnp.round((x - left) * s), 0.0, 255.0) / s + left


def qmatmul(a_i8, b_i8):
    """int8 x int8 -> int32 matmul."""
    return jnp.matmul(a_i8.astype(jnp.int32), b_i8.astype(jnp.int32))


def histogram(x, lo, hi, bins):
    """Fixed-range histogram; values outside [lo, hi) clamp to edge bins."""
    w = (hi - lo) / bins
    idx = jnp.clip(jnp.floor((x.reshape(-1) - lo) / w), 0, bins - 1).astype(
        jnp.int32
    )
    return jnp.zeros((bins,), jnp.int32).at[idx].add(1)
