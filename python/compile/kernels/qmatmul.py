"""L1 Pallas int8 GEMM simulation kernel.

int8 x int8 -> int32 with MXU-shaped 128x128 tiles (DESIGN.md §9). Used by
the int8-simulation artifacts and the kernel benches; the deployed integer
GEMM lives in the Rust engine (rust/src/int8/gemm.rs) and is tested against
this kernel's goldens.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TM = 128
TN = 128


def _qmm_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    o_ref[...] = jax.lax.dot_general(
        a,
        b,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@jax.jit
def qmatmul(a, b):
    """a: (M, K) int8, b: (K, N) int8 -> (M, N) int32."""
    m, k = a.shape
    _, n = b.shape
    grid = (pl.cdiv(m, TM), pl.cdiv(n, TN))
    return pl.pallas_call(
        _qmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, TN), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((TM, TN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a, b)


def _hist_kernel(x_ref, o_ref, *, lo, hi, bins):
    x = x_ref[...].reshape(-1)
    w = (hi - lo) / bins
    idx = jnp.clip(jnp.floor((x - lo) / w), 0, bins - 1).astype(jnp.int32)
    onehot = idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, bins), 1)
    o_ref[...] = jnp.sum(onehot.astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("lo", "hi", "bins"))
def histogram(x, lo, hi, bins=101):
    """Fixed-range histogram kernel (weight-distribution figures F1/F2)."""
    x2 = x.reshape(1, -1)
    return pl.pallas_call(
        functools.partial(_hist_kernel, lo=lo, hi=hi, bins=bins),
        out_shape=jax.ShapeDtypeStruct((bins,), jnp.int32),
        interpret=True,
    )(x2)
