"""AOT artifact builder: the only entry point of the Python build path.

``python -m compile.aot --outdir ../artifacts`` produces everything the Rust
runtime consumes; after this, Python is never on the request path.

Per model (artifacts/models/<name>/):
  raw.fatw / folded.fatw      raw and BN-folded weights
  graph.json / folded.json    graph IR (raw / folded, Rust cross-checks fold)
  sites.json                  quant sites, channel-stat nodes, orders
  fp_forward.hlo.txt          teacher/eval forward        (+ .manifest.json)
  calib_stats.hlo.txt         calibration statistics
  quant_fwd_<mode>.hlo.txt    fake-quant eval forward, 4 modes
  train_step_<mode>.hlo.txt   FAT fine-tune step, 4 modes
  quant_fwd_pw.hlo.txt / train_step_pw.hlo.txt  (§4.2, mobilenet only)

Shared (artifacts/):
  dataset/{train,val}_{x,y}.npy      cached SynthShapes tensors
  goldens/*.fatw                     cross-language test vectors
  manifest.json                      global index of all of the above

HLO *text* is the interchange format (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dataset as ds
from . import fatw, graph, interp, models, quantize, train

B_TRAIN = 32
B_EVAL = 100
B_CALIB = 25

PW_MODEL = "mobilenet_v2_mini"  # §4.2 experiment target

# Per-model pretraining epochs: tuned for the single-core build box.
# resnet_mini only feeds the Fig. 1-2 weight histograms, so it trains least.
EPOCHS = {
    "mobilenet_v2_mini": 5,
    "mnas_mini_10": 4,
    "mnas_mini_13": 4,
    "resnet_mini": 2,
}


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _render_path(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32", "int8": "i8", "uint8": "u8"}[
        np.dtype(dt).name
    ]


def _flat_spec(tree) -> list:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [
        {
            "name": _render_path(path),
            "shape": list(leaf.shape),
            "dtype": _dtype_name(leaf.dtype),
        }
        for path, leaf in leaves
    ]


def lower_artifact(outdir: str, name: str, fn, example_args) -> None:
    """Lower fn(*example_args) to HLO text + a marshalling manifest."""
    t0 = time.time()
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    out_shape = jax.eval_shape(fn, *example_args)
    manifest = {
        "name": name,
        "inputs": _flat_spec(example_args),
        "outputs": _flat_spec(out_shape),
    }
    with open(os.path.join(outdir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    with open(os.path.join(outdir, f"{name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(
        f"    lowered {name}: {len(text) / 1e6:.2f} MB HLO, "
        f"{len(manifest['inputs'])} in / {len(manifest['outputs'])} out "
        f"({time.time() - t0:.1f}s)"
    )


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def weights_spec(folded_params: dict) -> dict:
    return {k: sds(v.shape) for k, v in folded_params.items()}


# ---------------------------------------------------------------------------
# Dataset cache
# ---------------------------------------------------------------------------

def build_dataset(outdir: str):
    dsdir = os.path.join(outdir, "dataset")
    os.makedirs(dsdir, exist_ok=True)
    paths = {
        "train_x": os.path.join(dsdir, "train_x.npy"),
        "train_y": os.path.join(dsdir, "train_y.npy"),
        "val_x": os.path.join(dsdir, "val_x.npy"),
        "val_y": os.path.join(dsdir, "val_y.npy"),
    }
    if all(os.path.exists(p) for p in paths.values()):
        return {k: np.load(p) for k, p in paths.items()}
    print("  generating SynthShapes dataset ...")
    chunks_x, chunks_y = [], []
    for lo in range(0, ds.TRAIN_SIZE, 512):
        x, y = ds.train_batch(np.arange(lo, min(lo + 512, ds.TRAIN_SIZE)))
        chunks_x.append(x)
        chunks_y.append(y)
    tx, ty = np.concatenate(chunks_x), np.concatenate(chunks_y)
    chunks_x, chunks_y = [], []
    for lo in range(0, ds.VAL_SIZE, 512):
        x, y = ds.val_batch(np.arange(lo, min(lo + 512, ds.VAL_SIZE)))
        chunks_x.append(x)
        chunks_y.append(y)
    vx, vy = np.concatenate(chunks_x), np.concatenate(chunks_y)
    np.save(paths["train_x"], tx)
    np.save(paths["train_y"], ty)
    np.save(paths["val_x"], vx)
    np.save(paths["val_y"], vy)
    return {"train_x": tx, "train_y": ty, "val_x": vx, "val_y": vy}


# ---------------------------------------------------------------------------
# Per-model build
# ---------------------------------------------------------------------------

def build_model(outdir: str, name: str, data, epochs: int) -> dict:
    mdir = os.path.join(outdir, "models", name)
    os.makedirs(mdir, exist_ok=True)
    g = models.ZOO[name]()

    ck = os.path.join(mdir, "pretrained.npz")
    if os.path.exists(ck):
        z = np.load(ck)
        params = {k: z[k] for k in z.files if k != "__acc__"}
        acc = float(z["__acc__"]) if "__acc__" in z.files else -1.0
        print(f"  [{name}] cached pretrained model (val_acc={acc:.4f})")
    else:
        ep = epochs if epochs > 0 else EPOCHS.get(name, 4)
        print(f"  [{name}] pretraining ({ep} epochs) ...")
        params = graph.init_params(g, seed=abs(hash(name)) % (2**31))
        params, acc = train.pretrain(
            g,
            params,
            (data["train_x"], data["train_y"]),
            (data["val_x"], data["val_y"]),
            epochs=ep,
        )
        np.savez(ck, __acc__=np.float32(acc), **params)

    fg, fparams = graph.fold_bn(g, params)
    fatw.write(os.path.join(mdir, "raw.fatw"), params)
    fatw.write(os.path.join(mdir, "folded.fatw"), fparams)
    with open(os.path.join(mdir, "graph.json"), "w") as f:
        f.write(g.to_json())
    with open(os.path.join(mdir, "folded.json"), "w") as f:
        f.write(fg.to_json())

    sites = interp.enumerate_sites(fg)
    ch_nodes = interp.channel_stat_nodes(fg)
    with open(os.path.join(mdir, "sites.json"), "w") as f:
        json.dump(
            {
                "sites": [{"id": s, "unsigned": u} for s, u in sites],
                "channel_stats": [
                    {"id": nid, "channels": c} for nid, c in ch_nodes
                ],
                "weight_order": graph.folded_weight_order(fg),
                "trainable_order": {
                    cfg.name: sorted(quantize.trainable_init(fg, cfg))
                    for cfg in quantize.MODES.values()
                },
                "val_acc_fp_pretrain": acc,
            },
            f,
            indent=1,
        )

    wspec = weights_spec(fparams)
    xs_train = sds((B_TRAIN, ds.IMG, ds.IMG, ds.CHANNELS))
    xs_eval = sds((B_EVAL, ds.IMG, ds.IMG, ds.CHANNELS))
    xs_calib = sds((B_CALIB, ds.IMG, ds.IMG, ds.CHANNELS))
    act_t = sds((len(sites), 2))
    scalar = sds(())

    lower_artifact(
        mdir,
        "fp_forward",
        lambda w, x: interp.forward(fg, w, x),
        (wspec, xs_eval),
    )
    lower_artifact(
        mdir, "calib_stats", train.make_calib_stats(fg), (wspec, xs_calib)
    )
    lower_artifact(
        mdir,
        "calib_hist",
        train.make_calib_hist(fg),
        (wspec, act_t, xs_calib),
    )

    for cfg in quantize.MODES.values():
        tr0 = quantize.trainable_init(fg, cfg)
        trs = jax.tree_util.tree_map(lambda a: sds(a.shape), tr0)
        lower_artifact(
            mdir,
            f"quant_fwd_{cfg.name}",
            lambda w, t, tr, x, cfg=cfg: quantize.quant_forward(
                fg, cfg, w, t, tr, x
            ),
            (wspec, act_t, trs, xs_eval),
        )
        lower_artifact(
            mdir,
            f"train_step_{cfg.name}",
            train.make_fat_step(fg, cfg),
            (wspec, act_t, trs, trs, trs, scalar, scalar, xs_train),
        )

    if name == PW_MODEL:
        cfg = quantize.MODES["sym_scalar"]
        pw0 = quantize.pointwise_init(fg, fparams)
        pws = jax.tree_util.tree_map(lambda a: sds(a.shape), pw0)
        lower_artifact(
            mdir,
            "quant_fwd_pw",
            lambda w, t, pw, x: quantize.quant_forward_pointwise(
                fg, cfg, w, t, pw, x
            ),
            (wspec, act_t, pws, xs_eval),
        )
        lower_artifact(
            mdir,
            "train_step_pw",
            train.make_pointwise_step(fg, cfg),
            (wspec, act_t, pws, pws, pws, scalar, scalar, xs_train),
        )

    return {"graph": fg, "folded": fparams, "sites": sites, "acc": acc}


# ---------------------------------------------------------------------------
# Cross-language goldens
# ---------------------------------------------------------------------------

def build_goldens(outdir: str, built: dict, data) -> None:
    gdir = os.path.join(outdir, "goldens")
    os.makedirs(gdir, exist_ok=True)

    # 1. dataset bit-exactness
    gx, gy = ds.train_batch(np.arange(4))
    vx4, _ = ds.val_batch(np.arange(4))
    fatw.write(
        os.path.join(gdir, "dataset.fatw"),
        {"train4_x": gx, "train4_y": gy.astype(np.int32), "val4_x": vx4},
    )

    # 2. fake-quant kernel goldens (for the Rust quant module)
    from .kernels import ref

    rs = np.random.RandomState(7)
    x = rs.normal(0, 1.2, (64, 32)).astype(np.float32)
    tch = np.abs(rs.normal(1.0, 0.3, (32,))).astype(np.float32) + 0.2
    fatw.write(
        os.path.join(gdir, "fq.fatw"),
        {
            "x": x,
            "t_ch": tch,
            "sym_127_y": np.asarray(ref.fq_sym(x, 1.7)),
            "sym_u8_y": np.asarray(ref.fq_sym(np.abs(x), 2.1, unsigned=True)),
            "sym_ch_y": np.asarray(ref.fq_sym_ch(x, tch)),
            "asym_y": np.asarray(ref.fq_asym(x, -0.9, 3.3)),
        },
    )

    # 3. per-model: fp logits + calib stats + quant logits on fixed batches
    for name, info in built.items():
        fg, fparams = info["graph"], info["folded"]
        xb = data["val_x"][:B_EVAL]
        logits = np.asarray(interp.forward(fg, fparams, xb))
        cb = data["train_x"][:B_CALIB]
        site_mm, ch = train.make_calib_stats(fg)(fparams, cb)
        tens = {
            "x": xb,
            "fp_logits": logits,
            "calib_x": cb,
            "site_minmax": np.asarray(site_mm),
        }
        for k, v in ch.items():
            tens[k.replace(":", "_")] = np.asarray(v)
        for cfg_name in ("sym_scalar", "asym_vector"):
            cfg = quantize.MODES[cfg_name]
            tr0 = quantize.trainable_init(fg, cfg)
            ql = quantize.quant_forward(fg, cfg, fparams, site_mm, tr0, xb)
            tens[f"quant_logits_{cfg_name}"] = np.asarray(ql)
        fatw.write(os.path.join(gdir, f"model_{name}.fatw"), tens)
        print(f"    goldens for {name} written")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--epochs", type=int, default=0, help="override per-model EPOCHS"
    )
    ap.add_argument(
        "--models", default=",".join(models.ZOO), help="comma-separated subset"
    )
    ap.add_argument("--skip-goldens", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    t0 = time.time()
    data = build_dataset(args.outdir)
    built = {}
    for name in args.models.split(","):
        built[name] = build_model(args.outdir, name, data, args.epochs)
    if not args.skip_goldens:
        build_goldens(args.outdir, built, data)

    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(
            {
                "models": {
                    n: {"val_acc_fp": b["acc"], "num_sites": len(b["sites"])}
                    for n, b in built.items()
                },
                "batch_sizes": {
                    "train": B_TRAIN,
                    "eval": B_EVAL,
                    "calib": B_CALIB,
                },
                "dataset": {
                    "train_size": ds.TRAIN_SIZE,
                    "val_size": ds.VAL_SIZE,
                    "calib_size": ds.CALIB_SIZE,
                    "img": ds.IMG,
                    "num_classes": ds.NUM_CLASSES,
                },
            },
            f,
            indent=1,
        )
    print(f"artifacts built in {time.time() - t0:.1f}s -> {args.outdir}")


if __name__ == "__main__":
    main()
