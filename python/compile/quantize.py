"""FAT fake-quantization: STE gradients + the quantized forward graph.

Implements the paper's §3.1:
  * symmetric trained thresholds  T_adj = clip(α, 0.5, 1.0) · T_cal   (eq.13)
  * asymmetric trained thresholds (left limit + width, eq. 21-23) with
    empiric clip ranges α_T ∈ [-0.2, 0.4] signed / [0, 0.4] unsigned and
    α_R ∈ [0.5, 1.0]
  * scalar (per-tensor) and vector (per-filter, §3.1.5) weight thresholds
  * STE derivatives for round (eq. 16-17) and clip (eq. 18-19)

The forward computation runs the L1 Pallas kernels; backward passes are the
exact STE expressions the kernels' forwards imply. ``jnp.clip`` on α already
has the eq.-19 derivative, so threshold adjustment stays plain jnp.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import interp
from .graph import GraphDef, folded_weight_order
from .kernels import fake_quant as K

# Empiric clip ranges (paper §3.1.3-3.1.4).
ALPHA_MIN, ALPHA_MAX = 0.5, 1.0
AT_MIN_SIGNED, AT_MAX = -0.2, 0.4
AT_MIN_UNSIGNED = 0.0
AR_MIN, AR_MAX = 0.5, 1.0


# ---------------------------------------------------------------------------
# STE-differentiable fake-quant primitives
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def fq_sym(x, t, unsigned=False):
    return K.fq_sym(x, t, unsigned=unsigned)


def _fq_sym_fwd(x, t, unsigned):
    y = K.fq_sym(x, t, unsigned=unsigned)
    return y, (x, t, y)


def _fq_sym_bwd(unsigned, res, gy):
    """Exact STE (round' = 1, clip' = eq. 19, quotient rule kept):
    in-range dy/dT = (y - x)/T (round residual); saturated dy/dT = ±1."""
    x, t, y = res
    if unsigned:
        in_range = (x >= 0.0) & (x <= t)
        sat = jnp.where(x > t, 1.0, 0.0)
    else:
        in_range = jnp.abs(x) <= t
        sat = jnp.sign(x) * (~in_range)
    dt = jnp.where(in_range, (y - x) / t, sat)
    gt = jnp.sum(gy * dt)
    return gy * in_range, gt.reshape(t.shape)


fq_sym.defvjp(_fq_sym_fwd, _fq_sym_bwd)


@jax.custom_vjp
def fq_sym_ch(x, t):
    return K.fq_sym_ch(x, t)


def _fq_sym_ch_fwd(x, t):
    y = K.fq_sym_ch(x, t)
    return y, (x, t, y)


def _fq_sym_ch_bwd(res, gy):
    x, t, y = res
    in_range = jnp.abs(x) <= t  # t broadcasts over the last axis
    dt = jnp.where(in_range, (y - x) / t, jnp.sign(x) * (~in_range))
    axes = tuple(range(x.ndim - 1))
    gt = jnp.sum(gy * dt, axis=axes)
    return gy * in_range, gt.reshape(t.shape)


fq_sym_ch.defvjp(_fq_sym_ch_fwd, _fq_sym_ch_bwd)


@jax.custom_vjp
def fq_asym(x, left, width):
    return K.fq_asym(x, left, width)


def _fq_asym_fwd(x, left, width):
    y = K.fq_asym(x, left, width)
    return y, (x, left, width, y)


def _fq_asym_bwd(res, gy):
    """Exact STE: in-range dy/dleft = 0, dy/dwidth = (y - x)/width;
    saturated plateaus track left (both) and width (upper only)."""
    x, left, width, y = res
    right = left + width
    in_range = (x >= left) & (x <= right)
    sat_hi = x > right
    gx = gy * in_range
    gl = jnp.sum(gy * (~in_range))
    dw = jnp.where(in_range, (y - x) / width, jnp.where(sat_hi, 1.0, 0.0))
    gw = jnp.sum(gy * dw)
    return gx, gl.reshape(left.shape), gw.reshape(width.shape)


fq_asym.defvjp(_fq_asym_fwd, _fq_asym_bwd)


# ---------------------------------------------------------------------------
# Threshold adjustment (differentiable through jnp.clip == eq. 19)
# ---------------------------------------------------------------------------

def adjust_sym(alpha, t_cal):
    return jnp.clip(alpha, ALPHA_MIN, ALPHA_MAX) * t_cal


def adjust_asym(alpha_t, alpha_r, t_l, t_r, unsigned: bool):
    at_min = AT_MIN_UNSIGNED if unsigned else AT_MIN_SIGNED
    r = t_r - t_l
    left = t_l + jnp.clip(alpha_t, at_min, AT_MAX) * r
    width = jnp.clip(alpha_r, AR_MIN, AR_MAX) * r
    return left, width


# ---------------------------------------------------------------------------
# Quantized forward over a folded graph
# ---------------------------------------------------------------------------

class QuantConfig:
    """Static quantization mode: (symmetric|asymmetric) x (scalar|vector)."""

    def __init__(self, asym: bool, vector: bool):
        self.asym = asym
        self.vector = vector

    @property
    def name(self) -> str:
        return ("asym" if self.asym else "sym") + (
            "_vector" if self.vector else "_scalar"
        )


MODES = {
    m.name: m
    for m in (
        QuantConfig(False, False),
        QuantConfig(False, True),
        QuantConfig(True, False),
        QuantConfig(True, True),
    )
}


def trainable_init(g: GraphDef, cfg: QuantConfig) -> dict:
    """Initial trainable pytree: α=1 (sym), α_T=0, α_R=1 (asym).

    Keys are strings; jax tree flattening sorts dict keys, which fixes the
    marshalling order recorded in the artifact manifest.
    """
    sites = interp.enumerate_sites(g)
    tr = {}
    if cfg.asym:
        tr["act_at"] = jnp.zeros((len(sites),), jnp.float32)
        tr["act_ar"] = jnp.ones((len(sites),), jnp.float32)
    else:
        tr["act_a"] = jnp.ones((len(sites),), jnp.float32)
    for n in g.conv_like():
        if cfg.vector and n.op != "dense":
            ch = n.attrs.get("cout", n.attrs.get("ch"))
            tr[f"w_a:{n.id}"] = jnp.ones((ch,), jnp.float32)
        else:
            tr[f"w_a:{n.id}"] = jnp.ones((), jnp.float32)
    return tr


def quant_forward(
    g: GraphDef, cfg: QuantConfig, weights: dict, act_t, trainable: dict, x
):
    """Fake-quantized forward.

    weights: folded param dict. act_t: (S, 2) per-site calibration (min, max)
    stacked in site order. trainable: see trainable_init.
    """
    sites = interp.enumerate_sites(g)
    site_idx = {nid: i for i, (nid, _) in enumerate(sites)}
    site_unsigned = {nid: u for nid, u in sites}

    def weight_hook(n, w):
        a = trainable[f"w_a:{n.id}"]
        if a.ndim == 1:
            t_max = jax.lax.stop_gradient(
                jnp.max(jnp.abs(w.reshape(-1, w.shape[-1])), axis=0)
            )
            # Guard: an all-zero filter would give t=0 => S=inf.
            t = adjust_sym(a, jnp.maximum(t_max, 1e-8))
            return fq_sym_ch(w, t)
        t_max = jax.lax.stop_gradient(jnp.max(jnp.abs(w)))
        t = adjust_sym(a, jnp.maximum(t_max, 1e-8))
        return fq_sym(w, t, False)

    def act_hook(nid, v):
        i = site_idx[nid]
        unsigned = site_unsigned[nid]
        t_l, t_r = act_t[i, 0], act_t[i, 1]
        if cfg.asym:
            at = trainable["act_at"][i]
            ar = trainable["act_ar"][i]
            left, width = adjust_asym(at, ar, t_l, t_r, unsigned)
            width = jnp.maximum(width, 1e-8)
            return fq_asym(v, left, width)
        a = trainable["act_a"][i]
        t_cal = jnp.maximum(jnp.maximum(jnp.abs(t_l), jnp.abs(t_r)), 1e-8)
        t = adjust_sym(a, t_cal)
        return fq_sym(v, t, unsigned)

    return interp.forward(
        g, weights, x, weight_hook=weight_hook, act_hook=act_hook
    )


def quant_forward_pointwise(
    g: GraphDef, cfg: QuantConfig, weights: dict, act_t, pw: dict, x
):
    """§4.2 variant: fixed thresholds (α=1), trainable point-wise weight and
    bias scales clipped to [0.75, 1.25]."""
    eff = dict(weights)
    for name in folded_weight_order(g):
        eff[name] = weights[name] * jnp.clip(pw[f"pw:{name}"], 0.75, 1.25)
    frozen = jax.tree_util.tree_map(
        jax.lax.stop_gradient, trainable_init(g, cfg)
    )
    return quant_forward(g, cfg, eff, act_t, frozen, x)


def pointwise_init(g: GraphDef, weights: dict) -> dict:
    return {
        f"pw:{name}": jnp.ones_like(weights[name])
        for name in folded_weight_order(g)
    }
