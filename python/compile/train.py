"""Build-time training: FP pretraining and the FAT fine-tune step.

Pretraining (cross-entropy, batch-stats BN with EMA running stats) happens
once inside ``make artifacts`` to stand in for the paper's pretrained
TF-slim checkpoints (DESIGN.md §2).

The FAT fine-tune step (paper §3.2 + §4.1.2) is what gets AOT-lowered and
driven from Rust: RMSE distillation between the FP teacher and the
fake-quant student, Adam on threshold-scale parameters only. The cosine
annealing schedule with optimizer reset lives in the Rust coordinator — the
step consumes (lr, step_in_cycle) as runtime scalars.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import interp, quantize
from .graph import GraphDef

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def adam_update(params, grads, m, v, step, lr):
    """One Adam step over arbitrary pytrees. step: 1-based f32 scalar."""
    b1, b2 = jnp.float32(ADAM_B1), jnp.float32(ADAM_B2)
    m = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree_util.tree_map(
        lambda a, g: b2 * a + (1 - b2) * g * g, v, grads
    )
    bc1 = 1.0 - jnp.power(b1, step)
    bc2 = 1.0 - jnp.power(b2, step)
    params = jax.tree_util.tree_map(
        lambda p, mm, vv: p
        - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + ADAM_EPS),
        params,
        m,
        v,
    )
    return params, m, v


def rmse_loss(z_teacher, z_student):
    """Paper eq. 25: H(z^T, z^A) = sqrt(sum_i (z_i^T - z_i^A)^2 / N)."""
    n = z_teacher.shape[0]
    return jnp.sqrt(jnp.sum((z_teacher - z_student) ** 2) / n)


# ---------------------------------------------------------------------------
# FAT fine-tune step (AOT-lowered; Python never runs this at runtime)
# ---------------------------------------------------------------------------

def make_fat_step(g: GraphDef, cfg: quantize.QuantConfig):
    def loss_fn(trainable, weights, act_t, x):
        z_t = interp.forward(g, weights, x)
        z_a = quantize.quant_forward(g, cfg, weights, act_t, trainable, x)
        return rmse_loss(jax.lax.stop_gradient(z_t), z_a)

    def step_fn(weights, act_t, trainable, m, v, step, lr, x):
        loss, grads = jax.value_and_grad(loss_fn)(
            trainable, weights, act_t, x
        )
        trainable, m, v = adam_update(trainable, grads, m, v, step, lr)
        return loss, trainable, m, v

    return step_fn


def make_pointwise_step(g: GraphDef, cfg: quantize.QuantConfig):
    """§4.2: train point-wise weight/bias scales in [0.75, 1.25]."""

    def loss_fn(pw, weights, act_t, x):
        z_t = interp.forward(g, weights, x)
        z_a = quantize.quant_forward_pointwise(
            g, cfg, weights, act_t, pw, x
        )
        return rmse_loss(jax.lax.stop_gradient(z_t), z_a)

    def step_fn(weights, act_t, pw, m, v, step, lr, x):
        loss, grads = jax.value_and_grad(loss_fn)(pw, weights, act_t, x)
        pw, m, v = adam_update(pw, grads, m, v, step, lr)
        return loss, pw, m, v

    return step_fn


def make_calib_stats(g: GraphDef):
    """Per-site (min, max) + per conv-like per-channel (min, max)."""
    sites = interp.enumerate_sites(g)
    ch_nodes = interp.channel_stat_nodes(g)

    def fn(weights, x):
        cap = {}
        interp.forward(g, weights, x, capture=cap)
        site_minmax = jnp.stack(
            [
                jnp.stack([cap[nid]["min"], cap[nid]["max"]])
                for nid, _ in sites
            ]
        )  # (S, 2)
        ch = {
            f"ch:{nid}": jnp.stack(
                [cap[nid]["ch_min"], cap[nid]["ch_max"]]
            )  # (2, C)
            for nid, _ in ch_nodes
        }
        return site_minmax, ch

    return fn


def make_calib_hist(g: GraphDef, bins: int = 64):
    """Second calibration pass: per-site histograms over [min, max] ranges
    from the first pass. Feeds the percentile/KL baseline calibrators in
    the Rust ablation study (A1)."""
    sites = interp.enumerate_sites(g)

    def fn(weights, act_t, x):
        rec = {}

        def hook(nid, t):
            rec[nid] = t
            return t

        interp.forward(g, weights, x, act_hook=hook)
        outs = []
        for i, (nid, _) in enumerate(sites):
            lo, hi = act_t[i, 0], act_t[i, 1]
            w = jnp.maximum(hi - lo, 1e-8) / bins
            idx = jnp.clip(
                jnp.floor((rec[nid].reshape(-1) - lo) / w), 0, bins - 1
            ).astype(jnp.int32)
            outs.append(jnp.zeros((bins,), jnp.int32).at[idx].add(1))
        return jnp.stack(outs)  # (S, bins)

    return fn


# ---------------------------------------------------------------------------
# FP pretraining (build-time only)
# ---------------------------------------------------------------------------

def pretrain(
    g: GraphDef,
    params: dict,
    train_xy,
    val_xy,
    *,
    epochs: int = 3,
    bs: int = 64,
    lr: float = 3e-3,
    bn_momentum: float = 0.9,
    subset: int = 5000,
    log=print,
):
    """Train the FP model with Adam + cosine LR. Returns trained params.

    `subset` bounds the train set: this box is single-core, so the build
    keeps pretraining to a few minutes per model (accuracy on SynthShapes
    saturates quickly; see EXPERIMENTS.md).
    """
    tx, ty = train_xy
    if subset and subset < tx.shape[0]:
        tx, ty = tx[:subset], ty[:subset]
    vx, vy = val_xy
    num_classes = g.num_classes

    trainable_keys = [
        k for k in params if not (k.endswith(".mean") or k.endswith(".var"))
    ]
    running = {
        k: jnp.asarray(v)
        for k, v in params.items()
        if k.endswith(".mean") or k.endswith(".var")
    }
    tr = {k: jnp.asarray(params[k]) for k in trainable_keys}

    from . import nn

    def loss_fn(tr, x, y):
        p = dict(tr)
        p.update(running)  # bn_train ignores running stats
        logits, bn_stats = interp.forward(g, p, x, bn_mode="train")
        return nn.softmax_xent(logits, y, num_classes), bn_stats

    @jax.jit
    def train_step(tr, m, v, running, step, lr_now, x, y):
        (loss, bn_stats), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(tr, x, y)
        tr, m, v = adam_update(tr, grads, m, v, step, lr_now)
        mom = jnp.float32(bn_momentum)
        new_running = dict(running)
        for nid, (bm, bv) in bn_stats.items():
            new_running[f"{nid}.mean"] = (
                mom * running[f"{nid}.mean"] + (1 - mom) * bm
            )
            new_running[f"{nid}.var"] = (
                mom * running[f"{nid}.var"] + (1 - mom) * bv
            )
        return loss, tr, m, v, new_running

    @jax.jit
    def eval_logits(p, x):
        return interp.forward(g, p, x)

    zeros = jax.tree_util.tree_map(jnp.zeros_like, tr)
    m, v = zeros, jax.tree_util.tree_map(jnp.zeros_like, tr)
    n = tx.shape[0]
    steps_per_epoch = n // bs
    total = epochs * steps_per_epoch
    rs = np.random.RandomState(1234)
    step = 0
    for ep in range(epochs):
        perm = rs.permutation(n)
        ep_loss = 0.0
        for i in range(steps_per_epoch):
            idx = perm[i * bs : (i + 1) * bs]
            step += 1
            lr_now = jnp.float32(
                0.5 * lr * (1.0 + np.cos(np.pi * step / total))
            )
            loss, tr, m, v, running = train_step(
                tr,
                m,
                v,
                running,
                jnp.float32(step),
                lr_now,
                tx[idx],
                ty[idx],
            )
            ep_loss += float(loss)
        p = dict(tr)
        p.update(running)
        acc = evaluate(eval_logits, p, vx, vy, bs=200)
        log(
            f"  [{g.name}] epoch {ep + 1}/{epochs} "
            f"loss={ep_loss / steps_per_epoch:.4f} val_acc={acc:.4f}"
        )
    out = {k: np.asarray(val) for k, val in tr.items()}
    out.update({k: np.asarray(val) for k, val in running.items()})
    return out, acc


def evaluate(eval_logits, params, x, y, bs=200) -> float:
    correct = 0
    for i in range(0, x.shape[0], bs):
        logits = eval_logits(params, x[i : i + bs])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i : i + bs]))
    return correct / x.shape[0]
