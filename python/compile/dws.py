"""§3.3 reference: mutual rescaling of DWS -> [ReLU/ReLU6] -> Conv weights.

Equalises per-filter quantization thresholds of a depth-wise layer by
scaling filter k by s_k and dividing input channel k of the following 1x1
convolution by s_k. With ReLU6 the scaling must respect the saturation
plateau (paper eq. 26-27): channels whose calibrated pre-activation max
approaches 6.0 are *locked* (LOCK_LIMIT = 5.9), and scale factors of free
channels are capped so scaled outputs stay below 6.0.

This is the build-time/test reference; the runtime implementation is
``rust/src/quant/dws.rs`` (golden-tested against this one).
"""

from __future__ import annotations

import numpy as np

from .graph import GraphDef
from .interp import consumers

LOCK_LIMIT = 5.9
RELU6_CAP = 6.0
SCALE_MIN = 1.0 / 64.0
SCALE_MAX = 64.0


def find_patterns(g: GraphDef) -> list:
    """Return [(dw_id, act_id, conv_id, act_op)] for DWS->act->1x1-conv
    chains where the act output feeds only that conv (folded graph)."""
    cons = consumers(g)
    out = []
    for n in g.nodes:
        if n.op != "dwconv":
            continue
        cs = cons[n.id]
        if len(cs) != 1 or cs[0].op not in ("relu", "relu6"):
            continue
        act = cs[0]
        cs2 = cons[act.id]
        if len(cs2) != 1 or cs2[0].op != "conv" or cs2[0].attrs["k"] != 1:
            continue
        out.append((n.id, act.id, cs2[0].id, act.op))
    return out


def rescale_pattern(
    w_dw: np.ndarray,
    b_dw: np.ndarray,
    w_conv: np.ndarray,
    ch_max: np.ndarray,
    relu6: bool,
) -> tuple:
    """Compute and apply per-channel scales for one pattern.

    w_dw: (k,k,C), b_dw: (C,), w_conv: (1,1,C,Cout), ch_max: (C,) calibrated
    per-channel pre-activation maxima of the DWS output.
    Returns (w_dw', b_dw', w_conv', scales, locked_mask).
    """
    c = w_dw.shape[-1]
    t_k = np.abs(w_dw).reshape(-1, c).max(axis=0)  # paper step 1
    t_k = np.maximum(t_k, 1e-12)

    if relu6:
        locked = ch_max >= LOCK_LIMIT  # steps 2-3
    else:
        locked = np.zeros(c, dtype=bool)  # ReLU is scale-equivariant

    if locked.any():
        t0 = float(t_k[locked].mean())  # step 4
    else:
        t0 = float(t_k.mean())

    s = np.where(locked, 1.0, t0 / t_k)  # step 5
    if relu6:
        cap = RELU6_CAP / np.maximum(ch_max, 1e-12)  # step 6
        s = np.where(locked, 1.0, np.minimum(s, cap))
    s = np.clip(s, SCALE_MIN, SCALE_MAX).astype(np.float32)
    s = np.where(locked, np.float32(1.0), s)

    w_dw2 = (w_dw * s).astype(np.float32)
    b_dw2 = (b_dw * s).astype(np.float32)
    w_conv2 = (w_conv / s[None, None, :, None]).astype(np.float32)
    return w_dw2, b_dw2, w_conv2, s, locked


def rescale_model(g: GraphDef, params: dict, ch_max: dict) -> tuple:
    """Apply §3.3 to every pattern. ch_max: {dw_node_id: (C,) max}.

    Returns (new_params, report) where report lists per-pattern stats.
    """
    p = dict(params)
    report = []
    for dw_id, _act, conv_id, act_op in find_patterns(g):
        w_dw, b_dw, w_conv, s, locked = rescale_pattern(
            p[f"{dw_id}.w"],
            p[f"{dw_id}.b"],
            p[f"{conv_id}.w"],
            np.asarray(ch_max[dw_id]),
            relu6=(act_op == "relu6"),
        )
        p[f"{dw_id}.w"] = w_dw
        p[f"{dw_id}.b"] = b_dw
        p[f"{conv_id}.w"] = w_conv
        spread_before = _spread(params[f"{dw_id}.w"])
        spread_after = _spread(w_dw)
        report.append(
            {
                "dw": dw_id,
                "conv": conv_id,
                "act": act_op,
                "locked": int(locked.sum()),
                "channels": len(s),
                "spread_before": spread_before,
                "spread_after": spread_after,
            }
        )
    return p, report


def _spread(w: np.ndarray) -> float:
    """max/min ratio of per-filter thresholds — the quantity §3.3 shrinks."""
    c = w.shape[-1]
    t = np.abs(w).reshape(-1, c).max(axis=0)
    t = np.maximum(t, 1e-12)
    return float(t.max() / t.min())
