"""Stateless, portable PRNG shared (bit-exactly) with the Rust data layer.

The SynthShapes generator must produce *identical* float32 images in Python
(build-time: pretraining, goldens) and Rust (run-time: calibration and
fine-tuning batches). Sequential-stream PRNGs are hostile to vectorisation,
so everything is derived from a stateless splitmix64-style hash of
``(seed, index, slot, x, y, c)``. All integer ops are wrapping u64; floats
are produced from the top 24 bits, so every value is exactly representable
and the float path is pure IEEE-754 f32 arithmetic on both sides.

Rust mirror: ``rust/src/data/prng.rs`` (golden vectors in both test suites).
"""

from __future__ import annotations

import numpy as np

_M1 = np.uint64(0x9E3779B97F4A7C15)
_M2 = np.uint64(0xC2B2AE3D27D4EB4F)
_M3 = np.uint64(0x165667B19E3779F9)
_S1 = np.uint64(0xBF58476D1CE4E5B9)
_S2 = np.uint64(0x94D049BB133111EB)

# Hash "slots" partition the key space per sample. Slots 0..63 are scalar
# sample parameters; pixel-indexed draws use the slots below.
SLOT_NOISE = 64
SLOT_OUTLIER = 65

_INV24 = np.float32(1.0 / 16777216.0)  # 2^-24, exact


def splitmix64(z: np.ndarray) -> np.ndarray:
    """Finalising mix of splitmix64 over u64 (vectorised, wrapping)."""
    z = np.asarray(z, dtype=np.uint64)
    with np.errstate(over="ignore"):
        z = (z ^ (z >> np.uint64(30))) * _S1
        z = (z ^ (z >> np.uint64(27))) * _S2
        return z ^ (z >> np.uint64(31))


def hash_u64(seed, index, slot, x=0, y=0, c=0) -> np.ndarray:
    """Stateless u64 hash of the full key tuple (all args broadcastable)."""
    seed = np.asarray(seed, dtype=np.uint64)
    index = np.asarray(index, dtype=np.uint64)
    slot = np.asarray(slot, dtype=np.uint64)
    x = np.asarray(x, dtype=np.uint64)
    y = np.asarray(y, dtype=np.uint64)
    c = np.asarray(c, dtype=np.uint64)
    with np.errstate(over="ignore"):
        z = (
            seed * _M1
            ^ index * _M2
            ^ slot * _M3
            ^ (x << np.uint64(40))
            ^ (y << np.uint64(20))
            ^ c
        )
        # A second avalanche pass decorrelates the xor-of-products key.
        return splitmix64(splitmix64(z) + _M1)


def uniform(seed, index, slot, x=0, y=0, c=0) -> np.ndarray:
    """Uniform f32 in [0, 1) with 24-bit resolution (exact on both sides)."""
    h = hash_u64(seed, index, slot, x, y, c)
    return (h >> np.uint64(40)).astype(np.float32) * _INV24


def uniform_range(lo: float, hi: float, *key) -> np.ndarray:
    """lo + u*(hi-lo) with f32 constants — formula order mirrored in Rust."""
    u = uniform(*key)
    return np.float32(lo) + u * np.float32(hi - lo)
