"""Portable-PRNG unit tests. Golden values are duplicated in
rust/src/data/prng.rs tests — if you change one side, change both."""

import numpy as np

from compile import prng


def test_splitmix_golden():
    # Goldens mirrored in rust/src/data/prng.rs tests.
    assert int(prng.splitmix64(np.uint64(0))) == 0
    assert int(prng.splitmix64(np.uint64(1))) == 0x5692161D100B05E5
    assert int(prng.splitmix64(np.uint64(0xDEADBEEF))) == 0x4E062702EC929EEA
    assert int(prng.hash_u64(1, 2, 3, 4, 5, 6)) == 0x472D0DD1FD5C3C80
    assert int(prng.hash_u64(42, 7, 0)) == 0x66E2C29779EF6A7B
    assert float(prng.uniform(42, 7, 0)) == np.float32(0.40189755)
    assert float(
        prng.uniform(1, 0, prng.SLOT_NOISE, 3, 5, 2)
    ) == np.float32(0.103233337)


def test_hash_changes_with_every_key_component():
    base = int(prng.hash_u64(1, 2, 3, 4, 5, 6))
    assert base != int(prng.hash_u64(2, 2, 3, 4, 5, 6))
    assert base != int(prng.hash_u64(1, 3, 3, 4, 5, 6))
    assert base != int(prng.hash_u64(1, 2, 4, 4, 5, 6))
    assert base != int(prng.hash_u64(1, 2, 3, 5, 5, 6))
    assert base != int(prng.hash_u64(1, 2, 3, 4, 6, 6))
    assert base != int(prng.hash_u64(1, 2, 3, 4, 5, 7))


def test_uniform_range_and_resolution():
    idx = np.arange(100000, dtype=np.uint64)
    u = prng.uniform(42, idx, 0)
    assert u.dtype == np.float32
    assert float(u.min()) >= 0.0
    assert float(u.max()) < 1.0
    assert abs(float(u.mean()) - 0.5) < 0.01
    # exact representability: u * 2^24 must be integral
    scaled = u.astype(np.float64) * 16777216.0
    assert np.all(scaled == np.floor(scaled))


def test_uniform_vectorised_matches_scalar():
    idx = np.arange(16, dtype=np.uint64)
    vec = prng.uniform(7, idx, 3)
    for i in range(16):
        assert vec[i] == prng.uniform(7, np.uint64(i), 3)


def test_uniform_decorrelated_across_slots():
    idx = np.arange(4096, dtype=np.uint64)
    a = prng.uniform(1, idx, 0)
    b = prng.uniform(1, idx, 1)
    corr = np.corrcoef(a, b)[0, 1]
    assert abs(corr) < 0.05
