"""SynthShapes generator tests (shape, determinism, statistics)."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import dataset as ds


def test_shapes_and_dtypes():
    x, y = ds.train_batch(np.arange(8))
    assert x.shape == (8, ds.IMG, ds.IMG, ds.CHANNELS)
    assert x.dtype == np.float32
    assert y.dtype == np.int32
    assert list(y) == [0, 1, 2, 3, 4, 5, 6, 7]


def test_deterministic():
    a, _ = ds.train_batch(np.arange(16))
    b, _ = ds.train_batch(np.arange(16))
    assert np.array_equal(a, b)


def test_train_val_differ():
    a, _ = ds.train_batch(np.arange(4))
    b, _ = ds.val_batch(np.arange(4))
    assert not np.array_equal(a, b)


def test_value_range_and_outliers():
    x, _ = ds.train_batch(np.arange(64))
    assert float(x.min()) >= 0.0
    assert float(x.max()) <= 3.0
    frac = float((x > 1.25).mean())
    assert 0.001 < frac < 0.05  # sparse outliers exist (paper Fig. 1 driver)


def test_classes_visually_distinct():
    """Mean intra-class distance must be well below inter-class distance."""
    x, y = ds.train_batch(np.arange(200))
    flat = x.reshape(len(x), -1)
    cents = np.stack([flat[y == k].mean(axis=0) for k in range(10)])
    intra = np.mean(
        [np.linalg.norm(flat[y == k] - cents[k], axis=1).mean() for k in range(10)]
    )
    inter = np.mean(
        [
            np.linalg.norm(cents[i] - cents[j])
            for i in range(10)
            for j in range(10)
            if i != j
        ]
    )
    assert inter > 0.3 * intra  # separable enough to train on


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31), st.integers(0, 7))
def test_any_index_valid(idx, cnt):
    x, y = ds.train_batch(np.arange(idx, idx + cnt + 1))
    assert np.all(np.isfinite(x))
    assert x.shape[0] == cnt + 1
    assert np.all((y >= 0) & (y < 10))


def test_subset_helpers():
    ci = ds.calib_indices()
    assert len(ci) == 100
    fi = ds.finetune_indices()
    assert len(fi) == ds.TRAIN_SIZE // 10
    assert fi[1] - fi[0] == 10
