"""FAT fine-tune step, Adam, RMSE loss and the §4.2 point-wise step."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import graph, models, quantize, train


def _setup(model="resnet_mini", seed=5):
    g0 = models.ZOO[model]()
    g, p = graph.fold_bn(g0, graph.init_params(g0, seed=seed))
    x = np.random.RandomState(seed).rand(8, 32, 32, 3).astype(np.float32)
    mm, ch = train.make_calib_stats(g)(p, x)
    return g, p, x, mm, ch


def test_rmse_loss_matches_eq25():
    zt = jnp.float32([[1.0, 2.0], [3.0, 4.0]])
    za = jnp.float32([[1.5, 2.0], [3.0, 2.0]])
    want = np.sqrt((0.25 + 4.0) / 2.0)
    assert abs(float(train.rmse_loss(zt, za)) - want) < 1e-6


def test_adam_update_matches_reference():
    p = {"a": jnp.float32([1.0, 2.0])}
    g = {"a": jnp.float32([0.1, -0.2])}
    m = {"a": jnp.zeros(2)}
    v = {"a": jnp.zeros(2)}
    p2, m2, v2 = train.adam_update(p, g, m, v, jnp.float32(1.0), jnp.float32(0.01))
    # step 1: mhat = g, vhat = g^2 -> update = lr * g/(|g|+eps) = lr*sign(g)
    np.testing.assert_allclose(
        np.asarray(p2["a"]), [1.0 - 0.01, 2.0 + 0.01], atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(m2["a"]), [0.01, -0.02], atol=1e-7)


def test_fat_step_decreases_loss():
    g, p, x, mm, _ = _setup()
    cfg = quantize.MODES["sym_scalar"]
    tr = quantize.trainable_init(g, cfg)
    m = jax.tree_util.tree_map(jnp.zeros_like, tr)
    v = jax.tree_util.tree_map(jnp.zeros_like, tr)
    step = jax.jit(train.make_fat_step(g, cfg))
    losses = []
    for i in range(25):
        loss, tr, m, v = step(
            p, mm, tr, m, v, jnp.float32(i + 1), jnp.float32(5e-3), x
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.98, losses[:3] + losses[-3:]


def test_fat_step_trains_only_thresholds():
    g, p, x, mm, _ = _setup()
    cfg = quantize.MODES["asym_vector"]
    tr = quantize.trainable_init(g, cfg)
    m = jax.tree_util.tree_map(jnp.zeros_like, tr)
    v = jax.tree_util.tree_map(jnp.zeros_like, tr)
    step = jax.jit(train.make_fat_step(g, cfg))
    loss, tr2, m2, v2 = step(
        p, mm, tr, m, v, jnp.float32(1), jnp.float32(1e-2), x
    )
    changed = jax.tree_util.tree_map(
        lambda a, b: bool(np.any(np.asarray(a) != np.asarray(b))), tr, tr2
    )
    assert any(jax.tree_util.tree_leaves(changed))
    assert np.isfinite(float(loss))


def test_alpha_stays_useful_after_updates():
    """α may wander outside [0.5, 1] but T_adj stays clipped (eq. 12)."""
    g, p, x, mm, _ = _setup("mnas_mini_10")
    cfg = quantize.MODES["sym_scalar"]
    tr = quantize.trainable_init(g, cfg)
    m = jax.tree_util.tree_map(jnp.zeros_like, tr)
    v = jax.tree_util.tree_map(jnp.zeros_like, tr)
    step = jax.jit(train.make_fat_step(g, cfg))
    for i in range(10):
        loss, tr, m, v = step(
            p, mm, tr, m, v, jnp.float32(i + 1), jnp.float32(5e-2), x
        )
    t_eff = quantize.adjust_sym(tr["act_a"], jnp.float32(1.0))
    assert float(jnp.min(t_eff)) >= 0.5 - 1e-6
    assert float(jnp.max(t_eff)) <= 1.0 + 1e-6


def test_pointwise_step_trains_stably():
    """The §4.2 point-wise step must move the scales without diverging.
    (Its accuracy effect is validated end-to-end by the E3 ladder bench.)"""
    g, p, x, mm, _ = _setup("mobilenet_v2_mini", seed=2)
    cfg = quantize.MODES["sym_scalar"]
    pw = quantize.pointwise_init(g, p)
    m = jax.tree_util.tree_map(jnp.zeros_like, pw)
    v = jax.tree_util.tree_map(jnp.zeros_like, pw)
    step = jax.jit(train.make_pointwise_step(g, cfg))
    losses = []
    for i in range(15):
        loss, pw, m, v = step(
            p, mm, pw, m, v, jnp.float32(i + 1), jnp.float32(3e-4), x
        )
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < losses[0] * 1.5  # no divergence
    moved = any(
        float(jnp.max(jnp.abs(l - 1.0))) > 1e-4
        for l in jax.tree_util.tree_leaves(pw)
    )
    assert moved
    # scales must respect the clip range semantics (values may exceed, the
    # effective scale is clipped; check the applied range)
    leaves = jax.tree_util.tree_leaves(pw)
    eff = [np.clip(np.asarray(l), 0.75, 1.25) for l in leaves]
    assert all((e >= 0.75).all() and (e <= 1.25).all() for e in eff)


def test_calib_stats_shapes_and_monotonicity():
    g, p, x, mm, ch = _setup("mnas_mini_10")
    from compile import interp

    sites = interp.enumerate_sites(g)
    assert np.asarray(mm).shape == (len(sites), 2)
    mm = np.asarray(mm)
    assert np.all(mm[:, 0] <= mm[:, 1])
    for k, v in ch.items():
        v = np.asarray(v)
        assert v.shape[0] == 2
        assert np.all(v[0] <= v[1])
