"""§3.3 DWS rescaling: pattern matching, FP-invariance, threshold equalisation."""

import numpy as np

from compile import dws, graph, interp, models, train


def _folded(model, seed=4):
    g0 = models.ZOO[model]()
    return graph.fold_bn(g0, graph.init_params(g0, seed=seed))


def test_pattern_matching_mobilenet():
    g, _ = _folded("mobilenet_v2_mini")
    pats = dws.find_patterns(g)
    # every inverted-residual block has a dw -> relu6 -> 1x1 proj conv chain
    assert len(pats) == 7
    for dw_id, act_id, conv_id, act_op in pats:
        assert act_op == "relu6"
        assert g.node(dw_id).op == "dwconv"
        assert g.node(conv_id).attrs["k"] == 1


def test_pattern_matching_mnas_uses_relu():
    g, _ = _folded("mnas_mini_10")
    pats = dws.find_patterns(g)
    assert len(pats) >= 5
    assert all(op == "relu" for *_, op in pats)


def test_rescale_preserves_fp_outputs_relu():
    """For ReLU patterns the rescale is exactly output-preserving."""
    g, p = _folded("mnas_mini_10")
    x = np.random.RandomState(0).rand(4, 32, 32, 3).astype(np.float32)
    _, ch = train.make_calib_stats(g)(p, x)
    ch_max = {k.split(":")[1]: np.asarray(v)[1] for k, v in ch.items()}
    before = np.asarray(interp.forward(g, p, x))
    p2, report = dws.rescale_model(g, p, ch_max)
    after = np.asarray(interp.forward(g, p2, x))
    np.testing.assert_allclose(before, after, rtol=2e-3, atol=2e-4)
    assert len(report) >= 5


def test_rescale_preserves_fp_outputs_relu6_on_calib_data():
    g, p = _folded("mobilenet_v2_mini")
    x = np.random.RandomState(1).rand(8, 32, 32, 3).astype(np.float32)
    _, ch = train.make_calib_stats(g)(p, x)
    ch_max = {k.split(":")[1]: np.asarray(v)[1] for k, v in ch.items()}
    before = np.asarray(interp.forward(g, p, x))
    p2, report = dws.rescale_model(g, p, ch_max)
    after = np.asarray(interp.forward(g, p2, x))
    # exact on the calibration data (scale caps enforce X*s <= 6)
    np.testing.assert_allclose(before, after, rtol=5e-3, atol=5e-4)


def test_rescale_shrinks_threshold_spread():
    g, p = _folded("mobilenet_v2_mini")
    x = np.random.RandomState(2).rand(8, 32, 32, 3).astype(np.float32)
    _, ch = train.make_calib_stats(g)(p, x)
    ch_max = {k.split(":")[1]: np.asarray(v)[1] for k, v in ch.items()}
    _, report = dws.rescale_model(g, p, ch_max)
    improved = sum(
        1 for r in report if r["spread_after"] <= r["spread_before"] * 1.01
    )
    assert improved >= len(report) * 0.7, report


def test_locked_channels_unchanged():
    k, c, cout = 3, 8, 6
    rs = np.random.RandomState(3)
    w_dw = rs.normal(0, 1, (k, k, c)).astype(np.float32)
    b_dw = rs.normal(0, 0.1, (c,)).astype(np.float32)
    w_conv = rs.normal(0, 1, (1, 1, c, cout)).astype(np.float32)
    ch_max = np.float32([1.0, 5.95, 2.0, 6.5, 0.5, 1.5, 3.0, 5.89])
    w2, b2, wc2, s, locked = dws.rescale_pattern(
        w_dw, b_dw, w_conv, ch_max, relu6=True
    )
    assert locked.tolist() == [False, True, False, True, False, False, False, False]
    np.testing.assert_array_equal(w2[..., 1], w_dw[..., 1])
    np.testing.assert_array_equal(wc2[:, :, 3, :], w_conv[:, :, 3, :])
    assert np.all(s[locked] == 1.0)


def test_scale_cap_respects_relu6():
    """Scaled activations must not exceed 6.0 (paper eq. 26 precondition)."""
    k, c, cout = 3, 4, 4
    rs = np.random.RandomState(4)
    w_dw = rs.normal(0, 1, (k, k, c)).astype(np.float32) * np.float32(
        [0.1, 1.0, 2.0, 0.5]
    )
    b_dw = np.zeros(c, np.float32)
    w_conv = rs.normal(0, 1, (1, 1, c, cout)).astype(np.float32)
    ch_max = np.float32([2.0, 3.0, 4.0, 5.0])
    _, _, _, s, locked = dws.rescale_pattern(
        w_dw, b_dw, w_conv, ch_max, relu6=True
    )
    assert np.all(ch_max * s <= 6.0 + 1e-4)


def test_resnet_has_no_patterns():
    g, _ = _folded("resnet_mini")
    assert dws.find_patterns(g) == []
