"""STE gradients and threshold-adjustment semantics (paper §3.1)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import quantize as qz


def test_fq_sym_ste_grad_x():
    # In-range elements pass gradient through; saturated elements block it.
    x = jnp.float32([-3.0, -0.5, 0.0, 0.5, 3.0])
    t = jnp.float32(1.0)
    g = jax.grad(lambda x: jnp.sum(qz.fq_sym(x, t, False) * 2.0))(x)
    np.testing.assert_allclose(g, [0.0, 2.0, 2.0, 2.0, 0.0])


def test_fq_sym_ste_grad_t():
    # dy/dT = sign(x) on saturated elements; (y-x)/T round residual in range
    # (exact STE with the quotient rule kept, paper eq. 16-19).
    x = jnp.float32([-3.0, 0.5, 3.0, 4.0])
    f = lambda t: jnp.sum(qz.fq_sym(x, t, False))
    g = jax.grad(f)(jnp.float32(1.0))
    y05 = float(np.round(0.5 * 127.0) / 127.0)
    want = -1.0 + (y05 - 0.5) + 1.0 + 1.0
    assert abs(float(g) - want) < 1e-6


def test_fq_sym_grad_nonzero_at_alpha_one():
    """The round-residual term makes T trainable even with no saturation —
    the property FAT training relies on at α=1 init."""
    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.normal(0, 0.3, (256,)).astype(np.float32))
    t = jnp.float32(float(jnp.max(jnp.abs(x))))  # exactly max|x|: no sat
    g = jax.grad(lambda t: jnp.sum(qz.fq_sym(x, t, False) ** 2))(t)
    assert float(jnp.abs(g)) > 0.0


def test_fq_sym_unsigned_grad():
    x = jnp.float32([-1.0, 0.5, 3.0])
    f = lambda t: jnp.sum(qz.fq_sym(x, t, True))
    g = jax.grad(f)(jnp.float32(1.0))
    y05 = float(np.round(0.5 * 255.0) / 255.0)
    # low clip plateau (x=-1) has zero T-derivative; x=0.5 residual; x=3 sat.
    assert abs(float(g) - ((y05 - 0.5) + 1.0)) < 1e-6
    gx = jax.grad(lambda x: jnp.sum(qz.fq_sym(x, jnp.float32(1.0), True)))(x)
    np.testing.assert_allclose(gx, [0.0, 1.0, 0.0])


def test_fq_sym_ch_grad_t_per_channel():
    x = jnp.float32([[0.5, 3.0], [-2.0, 0.1]])
    t = jnp.float32([1.0, 1.0])
    g = jax.grad(lambda t: jnp.sum(qz.fq_sym_ch(x, t)))(t)
    res = lambda v: float(np.round(v * 127.0) / 127.0) - v
    np.testing.assert_allclose(
        g, [res(0.5) - 1.0, 1.0 + res(0.1)], atol=1e-6
    )


def test_fq_asym_grads():
    x = jnp.float32([-5.0, 0.0, 5.0])
    left = jnp.float32(-1.0)
    width = jnp.float32(2.0)

    gl = jax.grad(lambda l: jnp.sum(qz.fq_asym(x, l, width)))(left)
    gw = jax.grad(lambda w: jnp.sum(qz.fq_asym(x, left, w)))(width)
    # low saturation + high saturation track left; only high tracks width
    # (plus the x=0 round residual).
    assert float(gl) == 2.0
    y0 = float(np.round(1.0 * 255.0 / 2.0) / (255.0 / 2.0) - 1.0)
    assert abs(float(gw) - (1.0 + (y0 - 0.0) / 2.0)) < 1e-6
    gx = jax.grad(lambda x: jnp.sum(qz.fq_asym(x, left, width)))(x)
    np.testing.assert_allclose(gx, [0.0, 1.0, 0.0])


def test_adjust_sym_clip_range():
    t = jnp.float32(10.0)
    assert float(qz.adjust_sym(jnp.float32(0.2), t)) == 5.0  # clipped at 0.5
    assert float(qz.adjust_sym(jnp.float32(2.0), t)) == 10.0  # clipped at 1.0
    assert abs(float(qz.adjust_sym(jnp.float32(0.75), t)) - 7.5) < 1e-6


def test_adjust_sym_grad_zero_outside_clip():
    t = jnp.float32(10.0)
    g_in = jax.grad(lambda a: qz.adjust_sym(a, t))(jnp.float32(0.75))
    g_out = jax.grad(lambda a: qz.adjust_sym(a, t))(jnp.float32(1.5))
    assert float(g_in) == 10.0
    assert float(g_out) == 0.0


def test_adjust_asym_empiric_ranges():
    t_l, t_r = jnp.float32(-2.0), jnp.float32(6.0)  # R = 8
    # signed: alpha_t clips to [-0.2, 0.4]
    left, width = qz.adjust_asym(
        jnp.float32(-1.0), jnp.float32(1.0), t_l, t_r, unsigned=False
    )
    assert abs(float(left) - (-2.0 + (-0.2) * 8.0)) < 1e-5
    assert float(width) == 8.0
    # unsigned: alpha_t clips to [0, 0.4]; alpha_r to [0.5, 1]
    left, width = qz.adjust_asym(
        jnp.float32(-1.0), jnp.float32(0.1), t_l, t_r, unsigned=True
    )
    assert float(left) == -2.0
    assert float(width) == 4.0


def test_trainable_init_shapes():
    from compile import graph, models

    g, _ = graph.fold_bn(
        models.mobilenet_v2_mini(),
        graph.init_params(models.mobilenet_v2_mini()),
    )
    tr = qz.trainable_init(g, qz.MODES["sym_vector"])
    # vector mode: conv/dwconv get per-channel alphas, dense scalar
    assert tr["w_a:head_dense"].shape == ()
    assert tr["w_a:stem_conv"].shape == (16,)
    tr2 = qz.trainable_init(g, qz.MODES["asym_scalar"])
    assert "act_at" in tr2 and "act_ar" in tr2 and "act_a" not in tr2
    assert all(v.ndim == 0 for k, v in tr2.items() if k.startswith("w_a:"))


def test_quant_forward_alpha_one_close_to_fp():
    """With α=1 and exact-max calibration, fake-quant ≈ FP (8-bit error)."""
    import numpy as np

    from compile import graph, interp, models, train

    g0 = models.resnet_mini()
    p0 = graph.init_params(g0, seed=3)
    g, p = graph.fold_bn(g0, p0)
    x = np.random.RandomState(0).rand(4, 32, 32, 3).astype(np.float32)
    mm, _ = train.make_calib_stats(g)(p, x)
    fp_logits = interp.forward(g, p, x)
    for mode in ("sym_scalar", "sym_vector", "asym_scalar", "asym_vector"):
        cfg = qz.MODES[mode]
        tr = qz.trainable_init(g, cfg)
        ql = qz.quant_forward(g, cfg, p, mm, tr, x)
        rel = float(
            jnp.linalg.norm(ql - fp_logits) / jnp.linalg.norm(fp_logits)
        )
        assert rel < 0.35, (mode, rel)


def test_pointwise_identity_at_one():
    from compile import graph, models, train

    g0 = models.mobilenet_v2_mini()
    g, p = graph.fold_bn(g0, graph.init_params(g0, seed=1))
    x = np.random.RandomState(1).rand(2, 32, 32, 3).astype(np.float32)
    mm, _ = train.make_calib_stats(g)(p, x)
    cfg = qz.MODES["sym_scalar"]
    pw = qz.pointwise_init(g, p)
    a = qz.quant_forward_pointwise(g, cfg, p, mm, pw, x)
    b = qz.quant_forward(g, cfg, p, mm, qz.trainable_init(g, cfg), x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
