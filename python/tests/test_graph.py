"""Graph IR, folding and site-enumeration tests."""

import json

import numpy as np

from compile import graph, interp, models, nn


def test_all_models_build_and_are_topo_ordered():
    for name, f in models.ZOO.items():
        g = f()
        seen = set()
        for n in g.nodes:
            for i in n.inputs:
                assert i in seen, f"{name}: {n.id} uses {i} before def"
            seen.add(n.id)
        assert g.nodes[0].op == "input"
        assert g.nodes[-1].op == "dense"


def test_json_round_trip():
    g = models.mnas_mini_10()
    d = json.loads(g.to_json())
    assert d["name"] == "mnas_mini_10"
    assert len(d["nodes"]) == len(g.nodes)
    assert d["nodes"][0]["op"] == "input"


def test_fold_bn_equivalence_all_models():
    rng = np.random.RandomState(0)
    x = rng.rand(2, 32, 32, 3).astype(np.float32)
    for name, f in models.ZOO.items():
        g = f()
        p = graph.init_params(g, seed=11)
        # randomise bn params so folding is non-trivial
        for k in p:
            if k.endswith(".mean"):
                p[k] = rng.normal(0, 0.4, p[k].shape).astype(np.float32)
            if k.endswith(".var"):
                p[k] = np.abs(rng.normal(1, 0.3, p[k].shape)).astype(np.float32) + 0.1
            if k.endswith(".gamma"):
                p[k] = rng.normal(1, 0.2, p[k].shape).astype(np.float32)
            if k.endswith(".beta"):
                p[k] = rng.normal(0, 0.2, p[k].shape).astype(np.float32)
        a = interp.forward(g, p, x)
        fg, fp = graph.fold_bn(g, p)
        b = interp.forward(fg, fp, x)
        np.testing.assert_allclose(a, b, atol=2e-3, rtol=1e-3)
        assert not any(n.op == "bn" for n in fg.nodes)


def test_folded_graph_has_biases():
    g = models.mobilenet_v2_mini()
    fg, fp = graph.fold_bn(g, graph.init_params(g))
    for n in fg.conv_like():
        assert n.attrs.get("bias"), n.id
        assert f"{n.id}.b" in fp


def test_sites_skip_pre_activation_tensors():
    g = models.mobilenet_v2_mini()
    fg, _ = graph.fold_bn(g, graph.init_params(g))
    sites = dict(interp.enumerate_sites(fg))
    # expand convs feed relu6 directly -> not sites; relu6 outputs are.
    assert "b0_exp_conv" not in sites
    assert "b0_exp_relu6" in sites and sites["b0_exp_relu6"] is True
    # projection convs (linear) are sites and signed
    assert "b0_proj_conv" in sites and sites["b0_proj_conv"] is False
    assert "input" in sites and sites["input"] is True
    # logits site
    assert "head_dense" in sites


def test_site_order_matches_topo_order():
    g = models.resnet_mini()
    fg, _ = graph.fold_bn(g, graph.init_params(g))
    order = [n.id for n in fg.nodes]
    sites = [s for s, _ in interp.enumerate_sites(fg)]
    assert sites == [i for i in order if i in set(sites)]


def test_channel_stat_nodes_cover_all_convs():
    g = models.mnas_mini_13()
    fg, _ = graph.fold_bn(g, graph.init_params(g))
    ch = dict(interp.channel_stat_nodes(fg))
    for n in fg.nodes:
        if n.op in ("conv", "dwconv"):
            assert n.id in ch
            assert ch[n.id] == n.attrs.get("cout", n.attrs.get("ch"))


def test_weight_order_deterministic_and_complete():
    g = models.mnas_mini_10()
    fg, fp = graph.fold_bn(g, graph.init_params(g))
    order = graph.folded_weight_order(fg)
    assert order == graph.folded_weight_order(fg)
    assert set(order) == set(fp.keys())


def test_mnas_width_scaling():
    g10 = models.mnas_mini_10()
    g13 = models.mnas_mini_13()
    w10 = g10.node("stem_conv").attrs["cout"]
    w13 = g13.node("stem_conv").attrs["cout"]
    assert w13 > w10


def test_relu6_saturates():
    import jax.numpy as jnp

    assert float(nn.relu6(jnp.float32(9.0))) == 6.0
    assert float(nn.relu6(jnp.float32(-2.0))) == 0.0
