"""L1 Pallas kernels vs pure-jnp oracles (hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import fake_quant as K
from compile.kernels import qmatmul as Q
from compile.kernels import ref

SHAPES = st.sampled_from(
    [(4,), (3, 5), (17, 9), (2, 7, 11), (2, 3, 3, 8), (300, 33), (1, 1)]
)


def _rand(shape, seed, scale=2.0):
    rs = np.random.RandomState(seed)
    return rs.normal(0, scale, shape).astype(np.float32)


@settings(max_examples=40, deadline=None)
@given(SHAPES, st.integers(0, 99), st.floats(0.1, 8.0), st.booleans())
def test_fq_sym_matches_ref(shape, seed, t, unsigned):
    x = _rand(shape, seed)
    if unsigned:
        x = np.abs(x)
    t = jnp.float32(t)
    got = K.fq_sym(jnp.asarray(x), t, unsigned=unsigned)
    want = ref.fq_sym(x, t, unsigned=unsigned)
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    st.sampled_from([(3, 4), (17, 8), (2, 3, 16), (5, 5, 2, 12), (257, 7)]),
    st.integers(0, 99),
)
def test_fq_sym_ch_matches_ref(shape, seed):
    x = _rand(shape, seed)
    c = shape[-1]
    rs = np.random.RandomState(seed + 1)
    t = (np.abs(rs.normal(1, 0.5, c)) + 0.1).astype(np.float32)
    got = K.fq_sym_ch(jnp.asarray(x), jnp.asarray(t))
    want = ref.fq_sym_ch(x, t)
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    SHAPES,
    st.integers(0, 99),
    st.floats(-4.0, 1.0),
    st.floats(0.2, 8.0),
)
def test_fq_asym_matches_ref(shape, seed, left, width):
    x = _rand(shape, seed)
    left = jnp.float32(left)
    width = jnp.float32(width)
    got = K.fq_asym(jnp.asarray(x), left, width)
    want = ref.fq_asym(x, left, width)
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-6)


def test_fq_sym_roundtrip_bound():
    """|x - fq(x)| <= step/2 for in-range x (quantization error bound)."""
    x = np.linspace(-1.5, 1.5, 1001).astype(np.float32)
    t = jnp.float32(1.5)
    y = np.asarray(K.fq_sym(jnp.asarray(x), t))
    step = 1.5 / 127.0
    assert np.max(np.abs(y - x)) <= step / 2 + 1e-6


def test_fq_sym_idempotent():
    x = _rand((64, 32), 3)
    t = jnp.float32(2.0)
    y1 = np.asarray(K.fq_sym(jnp.asarray(x), t))
    y2 = np.asarray(K.fq_sym(jnp.asarray(y1), t))
    np.testing.assert_allclose(y1, y2, atol=1e-6)


def test_fq_asym_grid_contains_left_edge():
    x = np.float32([-10.0, 10.0])
    y = np.asarray(K.fq_asym(jnp.asarray(x), jnp.float32(-1.0), jnp.float32(3.0)))
    assert y[0] == np.float32(-1.0)
    assert abs(y[1] - 2.0) < 1e-6


@settings(max_examples=20, deadline=None)
@given(
    st.sampled_from([(4, 8, 4), (16, 16, 16), (128, 64, 128), (130, 32, 257), (1, 1, 1)]),
    st.integers(0, 99),
)
def test_qmatmul_matches_ref(dims, seed):
    m, k, n = dims
    rs = np.random.RandomState(seed)
    a = rs.randint(-127, 128, (m, k), dtype=np.int8)
    b = rs.randint(-127, 128, (k, n), dtype=np.int8)
    got = Q.qmatmul(jnp.asarray(a), jnp.asarray(b))
    want = ref.qmatmul(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qmatmul_saturating_inputs_accumulate_in_i32():
    a = np.full((8, 512), 127, dtype=np.int8)
    b = np.full((512, 8), 127, dtype=np.int8)
    got = np.asarray(Q.qmatmul(jnp.asarray(a), jnp.asarray(b)))
    assert got[0, 0] == 127 * 127 * 512  # > i16 range: accumulator is i32


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 99), st.integers(2, 64))
def test_histogram_matches_ref(seed, bins):
    x = _rand((1000,), seed, scale=1.0)
    got = Q.histogram(jnp.asarray(x), -3.0, 3.0, bins=bins)
    want = ref.histogram(x, -3.0, 3.0, bins)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(np.asarray(got).sum()) == 1000
