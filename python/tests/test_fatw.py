"""FATW container + AOT manifest round-trip tests."""

import json
import os

import numpy as np
import pytest

from compile import fatw


def test_fatw_roundtrip(tmp_path):
    tensors = {
        "a.w": np.random.RandomState(0).randn(3, 4).astype(np.float32),
        "b": np.array([1, -7, 2**30], dtype=np.int32),
        "c": np.array([-128, 127], dtype=np.int8),
        "scalar": np.float32(3.5).reshape(()),
    }
    p = tmp_path / "t.fatw"
    fatw.write(str(p), tensors)
    back = fatw.read(str(p))
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == np.asarray(tensors[k]).dtype


def test_fatw_rejects_bad_magic(tmp_path):
    p = tmp_path / "bad.fatw"
    p.write_bytes(b"NOTMAGIC" + b"\x00" * 16)
    with pytest.raises(AssertionError):
        fatw.read(str(p))


ARTIFACTS = os.path.join(os.path.dirname(__file__), "../../artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "models")),
    reason="artifacts not built",
)
def test_manifests_are_self_consistent():
    """Every artifact manifest's inputs/outputs must carry valid shapes and
    every referenced .hlo.txt must exist (the Rust marshalling contract)."""
    mdir = os.path.join(ARTIFACTS, "models")
    checked = 0
    for model in os.listdir(mdir):
        d = os.path.join(mdir, model)
        for f in os.listdir(d):
            if not f.endswith(".manifest.json"):
                continue
            m = json.load(open(os.path.join(d, f)))
            assert os.path.exists(
                os.path.join(d, f.replace(".manifest.json", ".hlo.txt"))
            )
            for spec in m["inputs"] + m["outputs"]:
                assert spec["dtype"] in ("f32", "i32", "i8", "u8")
                assert all(
                    isinstance(dim, int) and dim > 0 for dim in spec["shape"]
                )
            checked += 1
    assert checked >= 10


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "models")),
    reason="artifacts not built",
)
def test_weight_order_matches_manifest():
    """Weights group of each artifact must equal sites.json weight_order
    sorted — the order jax flattens dict pytrees."""
    mdir = os.path.join(ARTIFACTS, "models")
    for model in os.listdir(mdir):
        d = os.path.join(mdir, model)
        if not os.path.exists(os.path.join(d, "sites.json")):
            continue  # model still being built
        sites = json.load(open(os.path.join(d, "sites.json")))
        man = json.load(open(os.path.join(d, "fp_forward.manifest.json")))
        wnames = [
            s["name"].split("/", 1)[1]
            for s in man["inputs"]
            if s["name"].startswith("0/")
        ]
        assert wnames == sorted(sites["weight_order"])
