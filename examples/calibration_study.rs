//! **Calibration study**: compares static threshold calibrators (max /
//! percentile / KL over activation histograms) against FAT's trained
//! thresholds — the motivation for training α rather than picking a
//! better static rule (paper §3.1). Each static calibrator runs through
//! the same `QuantSpec` path the launcher's `--calibrator` flag uses.
//!
//!   cargo run --release --example calibration_study -- [--model M]

use std::sync::Arc;

use anyhow::Result;
use fat::coordinator::PipelineConfig;
use fat::quant::calibrate::Calibrator;
use fat::quant::session::{CalibOpts, QuantSession, QuantSpec};
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(fat::artifacts_dir);
    let model = args.get_or("model", "mnas_mini_10");
    let val = args.usize_or("val", 500);
    let spec = QuantSpec::parse(args.get_or("mode", "sym_scalar"), "max")?;

    let reg = Arc::new(Registry::new(Arc::new(Runtime::cpu()?)));
    let session = QuantSession::open(reg, &artifacts, model)?;

    println!("=== calibration study: {model} [{}] ===", spec.mode().name());
    let fp = session.fp_accuracy(val)?;
    println!("FP: {:.2}%", fp * 100.0);

    let cal = session.calibrate(CalibOpts::images(100))?;
    let max_acc = cal.identity(&spec)?.quant_accuracy(val)?;
    println!("max calibrator (paper default): {:.2}%", max_acc * 100.0);

    for c in [
        Calibrator::Percentile(9999),
        Calibrator::Percentile(9990),
        Calibrator::Percentile(9900),
        Calibrator::Kl,
    ] {
        match cal.identity(&spec.with_calibrator(c)) {
            Ok(th) => println!(
                "{:>8} calibrator: {:.2}%",
                c.name(),
                th.quant_accuracy(val)? * 100.0
            ),
            Err(e) => {
                println!("(calibrator {} unavailable: {e})", c.name());
                break;
            }
        }
    }

    // FAT: trained thresholds (short schedule)
    let cfg = PipelineConfig {
        model: model.to_string(),
        mode: spec.mode().name().to_string(),
        val_images: val,
        max_steps: args.usize_or("max-steps", 60),
        epochs: 2,
        ..Default::default()
    };
    let fat_acc = cal
        .finetune(&spec, &cfg.finetune_opts(false), |_, _, _| {})?
        .quant_accuracy(val)?;
    println!("FAT trained thresholds: {:.2}%", fat_acc * 100.0);
    println!(
        "\nFAT vs best-static gap is the paper's core claim: trained scales \
         beat any static rule on DWS architectures."
    );
    Ok(())
}
