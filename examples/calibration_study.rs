//! **Calibration study**: compares static threshold calibrators (max /
//! percentile / KL over activation histograms) against FAT's trained
//! thresholds — the motivation for training α rather than picking a
//! better static rule (paper §3.1).
//!
//!   cargo run --release --example calibration_study -- [--model M]

use std::sync::Arc;

use anyhow::Result;
use fat::coordinator::{Pipeline, PipelineConfig};
use fat::quant::calibrate::{threshold_from_hist, Calibrator};
use fat::quant::export::QuantMode;
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(fat::artifacts_dir);
    let model = args.get_or("model", "mnas_mini_10");
    let val = args.usize_or("val", 500);
    let mode = QuantMode::parse(args.get_or("mode", "sym_scalar"))?;

    let reg = Arc::new(Registry::new(Arc::new(Runtime::cpu()?)));
    let p = Pipeline::new(reg, &artifacts, model)?;

    println!("=== calibration study: {model} [{}] ===", mode.name());
    let fp = p.fp_accuracy(val)?;
    println!("FP: {:.2}%", fp * 100.0);

    let stats = p.calibrate(100)?;
    let tr0 = p.identity_trainables(mode)?;
    let max_acc = p.quant_accuracy(mode, &stats, &tr0, val)?;
    println!("max calibrator (paper default): {:.2}%", max_acc * 100.0);

    match p.calibrate_hist(&stats, 100) {
        Ok(hists) => {
            for (name, cal) in [
                ("p99.99", Calibrator::Percentile(9999)),
                ("p99.9", Calibrator::Percentile(9990)),
                ("p99", Calibrator::Percentile(9900)),
                ("KL", Calibrator::Kl),
            ] {
                let mut adj = stats.clone();
                for (i, mm) in adj.site_minmax.iter_mut().enumerate() {
                    let t = threshold_from_hist(cal, &hists[i], mm.min, mm.max);
                    mm.min = mm.min.max(-t);
                    mm.max = mm.max.min(t);
                }
                let acc = p.quant_accuracy(mode, &adj, &tr0, val)?;
                println!("{name:>8} calibrator: {:.2}%", acc * 100.0);
            }
        }
        Err(e) => println!("(calib_hist artifact unavailable: {e})"),
    }

    // FAT: trained thresholds (short schedule)
    let cfg = PipelineConfig {
        model: model.to_string(),
        mode: mode.name().to_string(),
        val_images: val,
        max_steps: args.usize_or("max-steps", 60),
        epochs: 2,
        ..Default::default()
    };
    let (tr, _) = p.finetune(mode, &stats, &cfg, |_, _, _| {})?;
    let fat_acc = p.quant_accuracy(mode, &stats, &tr, val)?;
    println!("FAT trained thresholds: {:.2}%", fat_acc * 100.0);
    println!(
        "\nFAT vs best-static gap is the paper's core claim: trained scales \
         beat any static rule on DWS architectures."
    );
    Ok(())
}
