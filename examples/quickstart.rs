//! **Quickstart — the end-to-end driver** (DESIGN.md §5–§7).
//!
//! Runs the complete FAT system on a real small workload through the
//! staged `QuantSession` API, proving all layers compose:
//!
//!   1. open the model — the pretrained artifact directory when it
//!      exists, else a builtin model on the native FP32 backend
//!      (`artifacts/` is NOT required; a bare checkout works)
//!   2. evaluate FP accuracy (PJRT artifact or native executor)
//!   3. calibrate on the paper's 100 training images
//!   4. quantize (vector, asymmetric) without fine-tuning (`identity`)
//!   5. FAT fine-tune: RMSE distillation on the unlabeled 10% subset,
//!      Adam on threshold scales, cosine annealing with optimizer reset
//!   6. re-evaluate, export the int8 model into an `Int8Engine` serving
//!      handle (the mobile-deployment simulator), report the ladder.
//!
//!   cargo run --release --example quickstart -- [--full]
//!
//! `--full` uses the paper's schedule (6 epochs); the default is a
//! shortened schedule sized for the single-core CI box. Results land in
//! EXPERIMENTS.md §E2E. (On the native backend the builtin weights are
//! untrained, so the accuracy ladder is near chance — the pipeline
//! mechanics, loss curve and int8 agreement are what it demonstrates.)

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use fat::coordinator::PipelineConfig;
use fat::int8::serve::EngineOptions;
use fat::quant::session::{CalibOpts, QuantSession, QuantSpec};
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&["full"]);
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(fat::artifacts_dir);
    let model = args.get_or("model", "mnas_mini_10");
    let spec = QuantSpec::parse(
        args.get_or("mode", "asym_vector"),
        args.get_or("calibrator", "max"),
    )?;

    let mut cfg = PipelineConfig::default();
    cfg.model = model.to_string();
    cfg.mode = spec.mode().name().to_string();
    if !args.flag("full") {
        cfg = cfg.fast();
        cfg.max_steps = args.usize_or("max-steps", 60);
    }
    cfg.val_images = args.usize_or("val", cfg.val_images);

    println!("=== FAT quickstart: {model} [{}] ===", spec.mode().name());
    let rt = Arc::new(Runtime::cpu()?);
    println!(
        "PJRT platform: {} ({} device)",
        rt.platform(),
        rt.device_count()
    );
    let reg = Arc::new(Registry::new(rt));

    // stage 0: open (loads + BN-folds the model; falls back to the
    // builtin zoo + native backend when artifacts/ is absent)
    let session = QuantSession::open(reg, &artifacts, model)?;
    println!("backend: {}", session.core().backend_name());

    // 1-2: FP baseline through the AOT fp_forward artifact
    let t = Instant::now();
    let fp = session.fp_accuracy(cfg.val_images)?;
    println!(
        "[1] FP accuracy        {:.2}%   ({:.1}s)",
        fp * 100.0,
        t.elapsed().as_secs_f64()
    );

    // 3: calibration (paper: 100 images from the train set, unlabeled)
    let t = Instant::now();
    let cal = session.calibrate(CalibOpts::images(cfg.calib_images))?;
    println!(
        "[2] calibrated {} images → {} sites ({:.1}s)",
        cfg.calib_images,
        cal.stats().site_minmax.len(),
        t.elapsed().as_secs_f64()
    );

    // 4: quantization without fine-tuning (identity thresholds, α = 1)
    let q0 = cal.identity(&spec)?.quant_accuracy(cfg.val_images)?;
    println!("[3] quant, no finetune {:.2}%", q0 * 100.0);

    // 5: FAT fine-tuning (RMSE distillation, unlabeled)
    let t = Instant::now();
    let th = cal.finetune(&spec, &cfg.finetune_opts(false), |step, loss, _lr| {
        if step % 20 == 0 {
            println!("      step {step:>4}  rmse {loss:.4}");
        }
    })?;
    let losses = th.losses();
    println!(
        "[4] FAT fine-tune: {} steps, rmse {:.4} → {:.4} ({:.1}s)",
        losses.len(),
        losses.first().unwrap_or(&0.0),
        losses.last().unwrap_or(&0.0),
        t.elapsed().as_secs_f64()
    );

    // 6: re-evaluate + int8 deployment behind the serving handle
    let q1 = th.quant_accuracy(cfg.val_images)?;
    println!("[5] quant, FAT         {:.2}%", q1 * 100.0);

    let engine = th.serve(EngineOptions::default())?;
    let t = Instant::now();
    let val8 = cfg.val_images.clamp(100, 500);
    let a8 = fat::coordinator::evaluate::int8_accuracy(&engine, val8)?;
    let dt = t.elapsed().as_secs_f64();
    println!(
        "[6] int8 engine        {:.2}%  ({} int8 param bytes, {:.1} img/s)",
        a8 * 100.0,
        engine.param_bytes(),
        val8 as f64 / dt
    );

    println!("\nladder: FP {:.2} → no-FT {:.2} → FAT {:.2} → int8 {:.2}",
        fp * 100.0, q0 * 100.0, q1 * 100.0, a8 * 100.0);
    println!(
        "accuracy drop after FAT: {:.2}% (paper target: < 0.5% at full schedule)",
        (fp - q1) * 100.0
    );
    Ok(())
}
