//! **§3.3 walk-through**: mutual rescaling of DWS → ReLU6 → Conv weights
//! on MobileNet-v2, showing per-pattern threshold spreads, locked
//! channels, and FP-output preservation — the machinery behind the §4.2
//! ladder. Runs as a staged session: the `dws_rescale` stage transition
//! mutates the weights and re-calibrates automatically.
//!
//!   cargo run --release --example dws_rescaling

use std::sync::Arc;

use anyhow::Result;
use fat::quant::dws;
use fat::quant::session::{CalibOpts, QuantSession};
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(fat::artifacts_dir);
    let model = args.get_or("model", "mobilenet_v2_mini");
    let val = args.usize_or("val", 300);

    let reg = Arc::new(Registry::new(Arc::new(Runtime::cpu()?)));
    let session = QuantSession::open(reg, &artifacts, model)?;

    println!("=== §3.3 DWS rescaling on {model} ===");
    let patterns = dws::find_patterns(&session.core().graph);
    println!("found {} DWS→act→1x1-conv chains:", patterns.len());
    for pat in &patterns {
        println!(
            "  {} → {} → {}  (relu6={})",
            pat.dw, pat.act, pat.conv, pat.relu6
        );
    }

    // FP reference before rescaling
    let fp_before = session.fp_accuracy(val)?;

    let cal = session.calibrate(CalibOpts::images(100))?;
    drop(session); // rescale below then mutates the weights in place
    let cal = cal.dws_rescale()?;
    println!("\nper-pattern rescale report:");
    println!("  {:<22} {:>8} {:>14} {:>13}", "dw layer", "locked", "spread before", "spread after");
    for r in cal.rescale_reports() {
        println!(
            "  {:<22} {:>4}/{:<3} {:>14.2} {:>13.2}",
            r.dw, r.locked, r.channels, r.spread_before, r.spread_after
        );
    }

    // FP must be (near-)preserved: the rescale is function-preserving on
    // calibration-covered ranges (exactly so for ReLU patterns).
    let fp_after = cal.fp_accuracy(val)?;
    println!(
        "\nFP accuracy before/after rescale: {:.2}% / {:.2}%  (must match)",
        fp_before * 100.0,
        fp_after * 100.0
    );

    let reports = cal.rescale_reports();
    let mean_spread_before: f32 =
        reports.iter().map(|r| r.spread_before).sum::<f32>() / reports.len() as f32;
    let mean_spread_after: f32 =
        reports.iter().map(|r| r.spread_after).sum::<f32>() / reports.len() as f32;
    println!(
        "mean per-filter threshold spread: {mean_spread_before:.1} → {mean_spread_after:.1}"
    );
    Ok(())
}
