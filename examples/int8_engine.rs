//! **int8 engine study**: the deployment simulator in isolation —
//! latency/throughput of integer-only inference vs the PJRT f32 forward,
//! model-size accounting, and fake-quant agreement.
//!
//!   cargo run --release --example int8_engine -- [--model M] [--mode MODE]

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use fat::coordinator::Pipeline;
use fat::data::{Batcher, Split};
use fat::quant::export::QuantMode;
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;
use fat::util::threads::fat_threads;

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(fat::artifacts_dir);
    let model = args.get_or("model", "mobilenet_v2_mini");
    let mode = QuantMode::parse(args.get_or("mode", "sym_vector"))?;
    let val = args.usize_or("val", 300);

    let reg = Arc::new(Registry::new(Arc::new(Runtime::cpu()?)));
    let p = Pipeline::new(reg, &artifacts, model)?;

    println!("=== int8 engine: {model} [{}] ===", mode.name());
    let stats = p.calibrate(100)?;
    let trained = p.identity_trained(mode);
    let qm = p.export_int8(mode, &stats, &trained)?;

    // model size: int8 weights + int32 biases vs f32 weights
    let f32_bytes: usize =
        p.weights.values().map(|t| t.len() * 4).sum();
    println!(
        "model size: f32 {:.1} KiB → int8 {:.1} KiB ({:.2}x smaller)",
        f32_bytes as f64 / 1024.0,
        qm.param_bytes as f64 / 1024.0,
        f32_bytes as f64 / qm.param_bytes as f64
    );

    // agreement with the fake-quant AOT path
    let tr0 = p.identity_trainables(mode)?;
    let fake = p.quant_accuracy(mode, &stats, &tr0, val)?;
    let engine = fat::coordinator::experiments::int8_accuracy(&qm, val)?;
    println!(
        "accuracy: fake-quant (XLA) {:.2}%  vs int8 engine {:.2}%",
        fake * 100.0,
        engine * 100.0
    );

    // throughput: integer engine (thread sweep) vs PJRT f32 forward
    let batcher = Batcher::new(Split::Val, (0..200u64).collect(), 50);
    let batches: Vec<_> = batcher.epoch(0);

    println!("FAT_THREADS = {} (set FAT_THREADS=<n> to override)", fat_threads());
    let mut int8_ips = 0.0;
    let mut sweep = vec![1usize, 2, 4];
    if !sweep.contains(&fat_threads()) {
        sweep.push(fat_threads());
    }
    for &workers in &sweep {
        let t = Instant::now();
        for (x, _) in &batches {
            let _ = qm.run_batch_with(x, workers)?;
        }
        let ips = 200.0 / t.elapsed().as_secs_f64();
        println!("  int8 engine @ {workers} worker(s): {ips:.1} img/s");
        if workers == fat_threads() {
            int8_ips = ips; // the summary reports the configured count
        }
    }

    let art = p.artifact("fp_forward")?;
    // fp_forward expects batch 100; re-batch accordingly
    let b100 = Batcher::new(Split::Val, (0..200u64).collect(), 100);
    let t = Instant::now();
    for (x, _) in b100.epoch_iter(0) {
        let inputs = fat::coordinator::marshal::build_inputs(
            &art.manifest,
            &[
                fat::coordinator::marshal::Group::Map(&p.weights),
                fat::coordinator::marshal::Group::Single(&x),
            ],
        )?;
        let _ = art.execute(&inputs)?;
    }
    let f32_ips = 200.0 / t.elapsed().as_secs_f64();

    println!(
        "throughput: int8 engine {int8_ips:.1} img/s  |  PJRT f32 {f32_ips:.1} img/s"
    );
    println!("(XLA fuses + vectorises the f32 path; the int8 engine models a \
              mobile integer-only target — compare its accuracy, size and \
              integer-arithmetic properties, not absolute CPU speed)");
    Ok(())
}
