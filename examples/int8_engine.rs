//! **int8 engine study**: the deployment simulator in isolation —
//! latency/throughput of the `Int8Engine` serving handle vs the native
//! f32 forward, model-size accounting, fake-quant agreement, and the
//! raw-bytes `infer` path. Artifact-free: runs on the builtin zoo +
//! native backend when `artifacts/` is absent.
//!
//!   cargo run --release --example int8_engine -- [--model M] [--mode MODE]

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use fat::data::{Batcher, Split};
use fat::int8::serve::EngineOptions;
use fat::quant::session::{CalibOpts, QuantSession, QuantSpec};
use fat::runtime::{Registry, Runtime};
use fat::util::cli::Args;
use fat::util::threads::fat_threads;

fn main() -> Result<()> {
    let args = Args::parse(&[]);
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(fat::artifacts_dir);
    let model = args.get_or("model", "mobilenet_v2_mini");
    let spec = QuantSpec::parse(
        args.get_or("mode", "sym_vector"),
        args.get_or("calibrator", "max"),
    )?;
    let val = args.usize_or("val", 300);

    let reg = Arc::new(Registry::new(Arc::new(Runtime::cpu()?)));
    let session = QuantSession::open(reg, &artifacts, model)?;

    println!("=== int8 engine: {model} [{}] ===", spec.mode().name());
    let cal = session.calibrate(CalibOpts::images(100))?;
    let th = cal.identity(&spec)?;
    let engine = th.serve(EngineOptions::default())?;

    // model size: int8 weights + int32 biases vs f32 weights
    let f32_bytes: usize =
        session.core().weights.values().map(|t| t.len() * 4).sum();
    println!(
        "model size: f32 {:.1} KiB → int8 {:.1} KiB ({:.2}x smaller)",
        f32_bytes as f64 / 1024.0,
        engine.param_bytes() as f64 / 1024.0,
        f32_bytes as f64 / engine.param_bytes() as f64
    );

    // agreement with the fake-quant AOT path
    let fake = th.quant_accuracy(val)?;
    let acc = fat::coordinator::evaluate::int8_accuracy(&engine, val)?;
    println!(
        "accuracy: fake-quant {:.2}%  vs int8 engine {:.2}%",
        fake * 100.0,
        acc * 100.0
    );

    // single-image serving path: raw u8 pixels through Int8Engine::infer
    let (x0, _) = fat::data::loader::batch(Split::Val, &[0]);
    let bytes: Vec<u8> = x0
        .as_f32()?
        .iter()
        .map(|&v| (v * 255.0).round().clamp(0.0, 255.0) as u8)
        .collect();
    let logits = engine.infer(&bytes)?;
    println!(
        "infer(&[u8]): {} logits, argmax {}",
        logits.len(),
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0)
    );

    // throughput: serving handle (thread sweep) vs PJRT f32 forward
    let batcher = Batcher::new(Split::Val, (0..200u64).collect(), 50);
    let batches: Vec<_> = batcher.epoch(0);

    println!("FAT_THREADS = {} (set FAT_THREADS=<n> to override)", fat_threads());
    let mut int8_ips = 0.0;
    let mut sweep = vec![1usize, 2, 4];
    if !sweep.contains(&fat_threads()) {
        sweep.push(fat_threads());
    }
    for &workers in &sweep {
        let t = Instant::now();
        for (x, _) in &batches {
            let _ = engine.infer_batch_with(x, workers)?;
        }
        let ips = 200.0 / t.elapsed().as_secs_f64();
        println!("  int8 engine @ {workers} worker(s): {ips:.1} img/s");
        if workers == fat_threads() {
            int8_ips = ips; // the summary reports the configured count
        }
    }

    // f32 reference: the native FP32 executor over the same images
    let core = session.core();
    let prog = fat::fp::FpProgram::compile(
        &core.graph,
        &core.weights,
        &core.sites,
        None,
    )?;
    let t = Instant::now();
    for (x, _) in &batches {
        let _ = prog.run_batch(x, fat_threads())?;
    }
    let f32_ips = 200.0 / t.elapsed().as_secs_f64();

    println!(
        "throughput: int8 engine {int8_ips:.1} img/s  |  native f32 {f32_ips:.1} img/s"
    );
    println!("(the int8 engine models a mobile integer-only target — compare \
              its accuracy, size and integer-arithmetic properties; the f32 \
              row is the native backend's planned executor on the same \
              worker pool)");
    Ok(())
}
